//! Paged KV manager with importance-driven precision tiers (paper §II-C,
//! Table II).
//!
//! KV is managed as pages of [`PAGE_TOKENS`] tokens. Each page carries an
//! importance score (recency + attention-mass style signal supplied by the
//! runtime). A [`KvPolicy`] maps ranked pages to [`PageTier`]s:
//!
//! * `FullKv` — everything kept in BF16.
//! * `SlidingWindow(w)` — only the last `w` tokens kept.
//! * `TopK(k)` — top-k pages in BF16, the rest dropped (Quest-style).
//! * `DynamicQuant { bf16, fp8, fp4 }` — tier ladder: top pages BF16,
//!   next FP8-equivalent alias, next FP4-equivalent alias, rest dropped.
//!
//! Placement: hottest pages claim HBM (via [`super::HbmPartition`]); the
//! overflow lives on the CXL tier and is fetched through the precision
//! alias its tier prescribes — which is exactly the demand Mechanism II
//! converts into proportional DRAM traffic.

use std::collections::BTreeMap;

use crate::bitplane::PrecisionView;
use crate::cxl::{shard_of, STRIPE_BYTES};

/// Tokens per KV page (Quest-style page granularity).
pub const PAGE_TOKENS: usize = 16;

/// Precision tier of a page.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PageTier {
    /// Full BF16 (lossless path).
    Bf16,
    /// FP8-equivalent alias view (sign + exp + 3 mantissa planes... 12 bits
    /// returned; modeled as the paper's FP8 tier).
    Fp8,
    /// FP4-equivalent alias view (sign + exp, mantissa dropped).
    Fp4,
    /// Evicted.
    Dropped,
}

impl PageTier {
    /// The alias view the device serves for this tier (BF16 substrate).
    pub fn view(self) -> Option<PrecisionView> {
        match self {
            PageTier::Bf16 => Some(PrecisionView::bf16_mantissa(7, 0)),
            PageTier::Fp8 => Some(PrecisionView::bf16_mantissa(3, 1)),
            PageTier::Fp4 => Some(PrecisionView::bf16_mantissa(0, 1)),
            PageTier::Dropped => None,
        }
    }

    /// Effective stored/fetched bits per element.
    pub fn bits(self) -> usize {
        match self {
            PageTier::Bf16 => 16,
            PageTier::Fp8 => 12, // sign + 8 exp + 3 man on the BF16 substrate
            PageTier::Fp4 => 9,  // sign + 8 exp
            PageTier::Dropped => 0,
        }
    }
}

/// Page-level KV policy (paper Table II rows).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KvPolicy {
    FullKv,
    SlidingWindow(usize),
    TopK(usize),
    DynamicQuant { bf16: usize, fp8: usize, fp4: usize },
}

impl KvPolicy {
    pub fn name(&self) -> String {
        match self {
            KvPolicy::FullKv => "Full KV Cache".into(),
            KvPolicy::SlidingWindow(w) => format!("Sliding Window ({w} tokens)"),
            KvPolicy::TopK(k) => format!("Quest (Top {k} pages in BF16)"),
            KvPolicy::DynamicQuant { bf16, fp8, fp4 } => {
                format!("Dynamic Quant. (Top {bf16} BF16, Next {fp8} FP8, Next {fp4} FP4)")
            }
        }
    }

    /// Assign tiers to pages given importance scores (higher = hotter).
    /// `page_of_token(t) = t / PAGE_TOKENS`; the final (current) page is
    /// always kept in BF16 (it is being appended).
    pub fn assign(&self, importance: &[f64]) -> Vec<PageTier> {
        let n = importance.len();
        let mut tiers = vec![PageTier::Dropped; n];
        if n == 0 {
            return tiers;
        }
        // rank pages by importance, excluding the live page (always BF16)
        let mut order: Vec<usize> = (0..n - 1).collect();
        order.sort_by(|&a, &b| importance[b].partial_cmp(&importance[a]).unwrap());
        match *self {
            KvPolicy::FullKv => tiers = vec![PageTier::Bf16; n],
            KvPolicy::SlidingWindow(w) => {
                let keep_pages = w.div_ceil(PAGE_TOKENS);
                for i in n.saturating_sub(keep_pages)..n {
                    tiers[i] = PageTier::Bf16;
                }
            }
            KvPolicy::TopK(k) => {
                for &p in order.iter().take(k) {
                    tiers[p] = PageTier::Bf16;
                }
            }
            KvPolicy::DynamicQuant { bf16, fp8, fp4 } => {
                for (rank, &p) in order.iter().enumerate() {
                    tiers[p] = if rank < bf16 {
                        PageTier::Bf16
                    } else if rank < bf16 + fp8 {
                        PageTier::Fp8
                    } else if rank < bf16 + fp8 + fp4 {
                        PageTier::Fp4
                    } else {
                        PageTier::Dropped
                    };
                }
            }
        }
        tiers[n - 1] = PageTier::Bf16;
        tiers
    }

    /// Bytes read per decode step under this policy, relative to FullKv
    /// (importance-ranked pages, equal page sizes).
    pub fn read_bytes_fraction(&self, n_pages: usize) -> f64 {
        if n_pages == 0 {
            return 1.0;
        }
        let imp: Vec<f64> = (0..n_pages).map(|i| 1.0 / (1.0 + i as f64)).collect();
        let tiers = self.assign(&imp);
        let total: usize = tiers.iter().map(|t| t.bits()).sum();
        total as f64 / (16 * n_pages) as f64
    }
}

/// Where a page currently lives.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PageHome {
    Hbm,
    Cxl,
}

/// One page's bookkeeping.
#[derive(Debug, Clone)]
pub struct PageMeta {
    pub seq: u64,
    pub index: usize,
    pub tier: PageTier,
    pub home: PageHome,
    pub importance: f64,
    /// Device block address when spilled.
    pub cxl_addr: Option<u64>,
    /// Which device shard serves the spilled page (0 when in HBM or when
    /// the tier runs a single device).
    pub shard: usize,
    /// When `Some(key)`, this page aliases a refcounted shared prefix
    /// block (RAG fan-out): it is always device-resident, never promoted
    /// to HBM, and its device copy is freed only when the last sharer
    /// releases it.
    pub shared_key: Option<u64>,
}

/// Refcount record for one shared prefix page (keyed by
/// `(prefix_key, page_index)`).
#[derive(Debug, Clone, Copy)]
struct SharedEntry {
    addr: u64,
    refs: u32,
}

/// The page manager for one serving engine. Spill addresses are handed out
/// at [`STRIPE_BYTES`] stride, so with an N-shard device consecutive
/// spilled pages interleave round-robin across shards (see
/// [`crate::cxl::ShardedDevice`]).
#[derive(Debug)]
pub struct KvPageManager {
    pub pages: Vec<PageMeta>,
    next_cxl_addr: u64,
    /// Shard count of the device tier this manager places onto.
    shards: usize,
    pub spilled_pages: u64,
    pub recalled_pages: u64,
    /// Live shared-prefix blocks: `(prefix_key, page_index)` → device
    /// address + sharer refcount.
    shared: BTreeMap<(u64, usize), SharedEntry>,
}

impl Default for KvPageManager {
    fn default() -> KvPageManager {
        KvPageManager::new()
    }
}

impl KvPageManager {
    pub fn new() -> KvPageManager {
        KvPageManager::with_shards(1)
    }

    /// A manager placing spilled pages onto an `shards`-way device tier.
    pub fn with_shards(shards: usize) -> KvPageManager {
        KvPageManager {
            pages: Vec::new(),
            next_cxl_addr: 0x1000_0000,
            shards: shards.max(1),
            spilled_pages: 0,
            recalled_pages: 0,
            shared: BTreeMap::new(),
        }
    }

    pub fn shards(&self) -> usize {
        self.shards
    }

    /// Register a new page for `seq`, placed in HBM if `fits`, else CXL at
    /// a shard-aware (stripe-interleaved) device address.
    pub fn add_page(&mut self, seq: u64, index: usize, fits_hbm: bool) -> &PageMeta {
        let home = if fits_hbm { PageHome::Hbm } else { PageHome::Cxl };
        let (cxl_addr, shard) = if fits_hbm {
            (None, 0)
        } else {
            self.spilled_pages += 1;
            let a = self.next_cxl_addr;
            self.next_cxl_addr += STRIPE_BYTES;
            (Some(a), shard_of(a, self.shards))
        };
        self.pages.push(PageMeta {
            seq,
            index,
            tier: PageTier::Bf16,
            home,
            importance: 1.0,
            cxl_addr,
            shard,
            shared_key: None,
        });
        self.pages.last().unwrap()
    }

    /// Register page `index` of `seq` as an alias of shared prefix block
    /// `(key, index)`. Returns the device address of the shared block and
    /// whether this call created it (`true`: the caller must write the
    /// page's data there; `false`: a prior sharer already did and the
    /// caller should read the authoritative content back). Shared pages
    /// live on the device unconditionally — they never occupy HBM, so one
    /// resident copy serves every sharer.
    pub fn add_shared_page(&mut self, seq: u64, index: usize, key: u64) -> (u64, bool) {
        let (addr, created) = match self.shared.get_mut(&(key, index)) {
            Some(e) => {
                e.refs += 1;
                (e.addr, false)
            }
            None => {
                let a = self.next_cxl_addr;
                self.next_cxl_addr += STRIPE_BYTES;
                self.spilled_pages += 1;
                self.shared.insert((key, index), SharedEntry { addr: a, refs: 1 });
                (a, true)
            }
        };
        self.pages.push(PageMeta {
            seq,
            index,
            tier: PageTier::Bf16,
            home: PageHome::Cxl,
            importance: 1.0,
            cxl_addr: Some(addr),
            shard: shard_of(addr, self.shards),
            shared_key: Some(key),
        });
        (addr, created)
    }

    /// Current sharer count of shared block `(key, index)` (0 if freed or
    /// never created).
    pub fn shared_refs(&self, key: u64, index: usize) -> u32 {
        self.shared.get(&(key, index)).map(|e| e.refs).unwrap_or(0)
    }

    /// Spilled-page count per shard (placement balance diagnostic).
    pub fn shard_loads(&self) -> Vec<usize> {
        let mut loads = vec![0usize; self.shards];
        for p in &self.pages {
            if p.cxl_addr.is_some() {
                loads[p.shard] += 1;
            }
        }
        loads
    }

    /// Pages of one sequence, in order.
    pub fn seq_pages(&self, seq: u64) -> Vec<&PageMeta> {
        let mut v: Vec<&PageMeta> = self.pages.iter().filter(|p| p.seq == seq).collect();
        v.sort_by_key(|p| p.index);
        v
    }

    /// Promote a spilled page of `seq` back to HBM residency: clears the
    /// device address so subsequent fetch plans skip it. Returns false if
    /// the page does not exist, is already HBM-resident, or aliases a
    /// shared prefix block (shared pages are pinned to the device — one
    /// copy serves every sharer). Residency changes like this are exactly
    /// what the engine's prefetch fence guards against — an in-flight
    /// prefetch of the old address is discarded, never consumed.
    pub fn promote(&mut self, seq: u64, index: usize) -> bool {
        for p in self.pages.iter_mut() {
            if p.seq == seq && p.index == index && p.home == PageHome::Cxl && p.shared_key.is_none()
            {
                p.home = PageHome::Hbm;
                p.cxl_addr = None;
                p.shard = 0;
                return true;
            }
        }
        false
    }

    /// Demote an HBM-resident page of `seq` to the CXL tier: allocates a
    /// fresh stripe-aligned device address (the caller must write the
    /// page's data there) and counts it as a spill. Returns the new
    /// address, or `None` if the page is missing or already CXL-resident.
    /// This is the inverse of [`Self::promote`] and is what the engine's
    /// preemption path uses to park a victim's hot pages on the device.
    pub fn demote(&mut self, seq: u64, index: usize) -> Option<u64> {
        for p in self.pages.iter_mut() {
            if p.seq == seq && p.index == index && p.home == PageHome::Hbm {
                let a = self.next_cxl_addr;
                self.next_cxl_addr += STRIPE_BYTES;
                p.home = PageHome::Cxl;
                p.cxl_addr = Some(a);
                p.shard = shard_of(a, self.shards);
                self.spilled_pages += 1;
                return Some(a);
            }
        }
        None
    }

    /// Remove one page's bookkeeping entirely, returning its record (the
    /// caller frees any device copy). The preemption path uses this for
    /// the saved partial live page, which is not a committed page and
    /// re-commits when it next fills during decode.
    pub fn remove_page(&mut self, seq: u64, index: usize) -> Option<PageMeta> {
        let i = self.pages.iter().position(|p| p.seq == seq && p.index == index)?;
        Some(self.pages.remove(i))
    }

    /// Re-tier a sequence's pages under a policy using current importance.
    pub fn retier(&mut self, seq: u64, policy: KvPolicy) {
        let mut idx: Vec<usize> = (0..self.pages.len()).filter(|&i| self.pages[i].seq == seq).collect();
        idx.sort_by_key(|&i| self.pages[i].index);
        let imp: Vec<f64> = idx.iter().map(|&i| self.pages[i].importance).collect();
        let tiers = policy.assign(&imp);
        for (k, &i) in idx.iter().enumerate() {
            self.pages[i].tier = tiers[k];
        }
    }

    /// Drop all pages of a finished sequence. Returns how many were
    /// HBM-resident (so the caller can return that capacity) and the
    /// device addresses whose blocks are now dead (so the caller can
    /// `Free` them — device footprint tracks live residency). A shared
    /// prefix page only contributes its address once its refcount drops
    /// to zero; earlier sharers release without freeing.
    pub fn release_seq(&mut self, seq: u64) -> (usize, Vec<u64>) {
        let mut in_hbm = 0usize;
        let mut spilled = Vec::new();
        for p in self.pages.iter().filter(|p| p.seq == seq) {
            match (p.cxl_addr, p.shared_key) {
                (Some(addr), Some(key)) => {
                    let e = self
                        .shared
                        .get_mut(&(key, p.index))
                        .expect("shared page has a live refcount entry");
                    e.refs -= 1;
                    if e.refs == 0 {
                        self.shared.remove(&(key, p.index));
                        spilled.push(addr);
                    }
                }
                (Some(addr), None) => spilled.push(addr),
                (None, _) => in_hbm += 1,
            }
        }
        self.pages.retain(|p| p.seq != seq);
        (in_hbm, spilled)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn imp(n: usize) -> Vec<f64> {
        (0..n).map(|i| 1.0 / (1.0 + i as f64)).collect() // page 0 hottest
    }

    #[test]
    fn full_keeps_everything() {
        let tiers = KvPolicy::FullKv.assign(&imp(10));
        assert!(tiers.iter().all(|&t| t == PageTier::Bf16));
    }

    #[test]
    fn sliding_window_keeps_tail() {
        let tiers = KvPolicy::SlidingWindow(32).assign(&imp(10));
        assert_eq!(tiers[9], PageTier::Bf16);
        assert_eq!(tiers[8], PageTier::Bf16);
        assert!(tiers[..8].iter().all(|&t| t == PageTier::Dropped));
    }

    #[test]
    fn topk_keeps_hottest_plus_live() {
        let tiers = KvPolicy::TopK(3).assign(&imp(10));
        let kept = tiers.iter().filter(|&&t| t == PageTier::Bf16).count();
        assert_eq!(kept, 4); // top-3 + live page
        assert_eq!(tiers[0], PageTier::Bf16); // hottest page kept
    }

    #[test]
    fn dynamic_quant_ladder() {
        let tiers = KvPolicy::DynamicQuant { bf16: 2, fp8: 2, fp4: 2 }.assign(&imp(10));
        assert_eq!(tiers[0], PageTier::Bf16);
        assert_eq!(tiers[1], PageTier::Bf16);
        assert_eq!(tiers[2], PageTier::Fp8);
        assert_eq!(tiers[3], PageTier::Fp8);
        assert_eq!(tiers[4], PageTier::Fp4);
        assert_eq!(tiers[5], PageTier::Fp4);
        assert_eq!(tiers[6], PageTier::Dropped);
        assert_eq!(tiers[9], PageTier::Bf16); // live
    }

    #[test]
    fn read_fraction_ordering() {
        // more aggressive policies read fewer bytes
        let full = KvPolicy::FullKv.read_bytes_fraction(16);
        let dq = KvPolicy::DynamicQuant { bf16: 5, fp8: 5, fp4: 0 }.read_bytes_fraction(16);
        let topk = KvPolicy::TopK(5).read_bytes_fraction(16);
        let sw = KvPolicy::SlidingWindow(64).read_bytes_fraction(16);
        assert_eq!(full, 1.0);
        assert!(dq < full && dq > topk, "dq={dq} topk={topk}");
        assert!(sw < dq);
    }

    #[test]
    fn tier_views_match_bits() {
        assert!(PageTier::Bf16.view().unwrap().is_full());
        assert_eq!(PageTier::Fp8.view().unwrap().returned_bits(), 12);
        assert_eq!(PageTier::Fp4.view().unwrap().returned_bits(), 9);
        assert!(PageTier::Dropped.view().is_none());
        assert!(PageTier::Bf16.bits() > PageTier::Fp8.bits());
    }

    #[test]
    fn manager_spill_accounting() {
        let mut m = KvPageManager::new();
        m.add_page(1, 0, true);
        m.add_page(1, 1, true);
        m.add_page(1, 2, false);
        assert_eq!(m.spilled_pages, 1);
        assert_eq!(m.seq_pages(1).len(), 3);
        assert!(m.seq_pages(1)[2].cxl_addr.is_some());
        m.retier(1, KvPolicy::DynamicQuant { bf16: 1, fp8: 1, fp4: 1 });
        let (hbm, spilled) = m.release_seq(1);
        assert_eq!(hbm, 2);
        assert_eq!(spilled.len(), 1, "spilled page addresses come back for device Free");
        assert!(m.pages.is_empty());
    }

    #[test]
    fn promote_clears_device_address() {
        let mut m = KvPageManager::new();
        m.add_page(1, 0, false);
        m.add_page(1, 1, true);
        assert!(m.seq_pages(1)[0].cxl_addr.is_some());
        assert!(m.promote(1, 0));
        let p = &m.seq_pages(1)[0];
        assert_eq!(p.home, PageHome::Hbm);
        assert!(p.cxl_addr.is_none());
        // idempotence / missing pages
        assert!(!m.promote(1, 0), "already HBM");
        assert!(!m.promote(1, 1), "was never spilled");
        assert!(!m.promote(2, 0), "unknown sequence");
        // release counts the promoted page as HBM-resident, and its old
        // device address is gone (nothing left to free)
        let (hbm, spilled) = m.release_seq(1);
        assert_eq!(hbm, 2);
        assert!(spilled.is_empty());
    }

    #[test]
    fn demote_allocates_address_and_counts_spill() {
        let mut m = KvPageManager::with_shards(4);
        m.add_page(1, 0, true);
        m.add_page(1, 1, false);
        let spilled_before = m.spilled_pages;
        let addr = m.demote(1, 0).expect("HBM page demotes");
        let p = &m.seq_pages(1)[0];
        assert_eq!(p.home, PageHome::Cxl);
        assert_eq!(p.cxl_addr, Some(addr));
        assert_eq!(p.shard, shard_of(addr, 4));
        assert_eq!(m.spilled_pages, spilled_before + 1);
        // invalid demotions: already CXL, unknown page/sequence
        assert!(m.demote(1, 0).is_none(), "already on the device");
        assert!(m.demote(1, 1).is_none(), "was spilled at commit");
        assert!(m.demote(2, 0).is_none(), "unknown sequence");
        // demote → promote round-trips back to HBM residency
        assert!(m.promote(1, 0));
        assert!(m.seq_pages(1)[0].cxl_addr.is_none());
    }

    #[test]
    fn remove_page_returns_record_and_forgets_it() {
        let mut m = KvPageManager::new();
        m.add_page(1, 0, false);
        m.add_page(1, 1, true);
        let meta = m.remove_page(1, 0).expect("page exists");
        assert_eq!(meta.index, 0);
        assert!(meta.cxl_addr.is_some(), "caller gets the address to free");
        assert_eq!(m.seq_pages(1).len(), 1);
        assert!(m.remove_page(1, 0).is_none(), "already removed");
        // the cumulative spill counter is history, not live state
        assert_eq!(m.spilled_pages, 1);
    }

    #[test]
    fn shared_pages_refcount_and_free_once() {
        let mut m = KvPageManager::with_shards(2);
        let key = 0xfeed;
        // first sharer creates both prefix blocks
        let (a0, c0) = m.add_shared_page(1, 0, key);
        let (a1, c1) = m.add_shared_page(1, 1, key);
        assert!(c0 && c1);
        assert_ne!(a0, a1);
        assert_eq!(m.spilled_pages, 2);
        // later sharers attach to the same addresses without new spills
        let (b0, c0b) = m.add_shared_page(2, 0, key);
        let (b1, c1b) = m.add_shared_page(2, 1, key);
        assert!(!c0b && !c1b);
        assert_eq!((a0, a1), (b0, b1));
        assert_eq!(m.spilled_pages, 2, "attach is not a spill");
        assert_eq!(m.shared_refs(key, 0), 2);
        // a different prefix key gets its own block
        let (other, created) = m.add_shared_page(3, 0, key + 1);
        assert!(created);
        assert_ne!(other, a0);
        // shared pages are pinned: promote refuses them
        assert!(!m.promote(1, 0), "shared page never promotes to HBM");
        // first release decrements; block stays live
        let (hbm, freed) = m.release_seq(1);
        assert_eq!(hbm, 0);
        assert!(freed.is_empty(), "seq 2 still shares the blocks");
        assert_eq!(m.shared_refs(key, 0), 1);
        // last release frees both blocks exactly once
        let (_, freed) = m.release_seq(2);
        let mut freed = freed;
        freed.sort_unstable();
        let mut want = vec![a0, a1];
        want.sort_unstable();
        assert_eq!(freed, want);
        assert_eq!(m.shared_refs(key, 0), 0);
        // re-sharing after a full release allocates a fresh block
        let (fresh, created) = m.add_shared_page(9, 0, key);
        assert!(created);
        assert_ne!(fresh, a0, "addresses are never reused");
    }

    #[test]
    fn shared_and_private_pages_coexist_per_sequence() {
        let mut m = KvPageManager::new();
        m.add_shared_page(1, 0, 7);
        m.add_page(1, 1, true);
        m.add_page(1, 2, false);
        let pages = m.seq_pages(1);
        assert_eq!(pages.len(), 3);
        assert_eq!(pages[0].shared_key, Some(7));
        assert!(pages[1].shared_key.is_none() && pages[2].shared_key.is_none());
        // sole sharer: release frees the shared block and the private spill
        let (hbm, freed) = m.release_seq(1);
        assert_eq!(hbm, 1);
        assert_eq!(freed.len(), 2);
    }

    #[test]
    fn sharded_placement_round_robins_spilled_pages() {
        let mut m = KvPageManager::with_shards(4);
        assert_eq!(m.shards(), 4);
        for i in 0..8 {
            m.add_page(1, i, false);
        }
        // stripe-strided addresses interleave cleanly: 2 pages per shard
        assert_eq!(m.shard_loads(), vec![2, 2, 2, 2]);
        // HBM pages don't count toward shard load
        m.add_page(2, 0, true);
        assert_eq!(m.shard_loads().iter().sum::<usize>(), 8);
        // consecutive spilled pages land on distinct shards
        let spilled: Vec<usize> = m
            .pages
            .iter()
            .filter(|p| p.cxl_addr.is_some())
            .map(|p| p.shard)
            .collect();
        assert_eq!(&spilled[..4], &[0, 1, 2, 3]);
    }
}
