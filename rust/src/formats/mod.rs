//! Element formats and floating-point field structure.
//!
//! TRACE operates below the numeric format: it stores *whatever bits the host
//! wrote* as bit-planes. But the evaluation needs the formats themselves —
//! BF16 as the reference KV/weight format, FP8-E4M3 / INT8 / INT4 / MXFP4 as
//! the quantized bases of Table IV and Figs 17–21, and the (sign, exponent,
//! mantissa) field split that defines which planes are "compressible core"
//! vs "elastic detail" (paper Fig. 7) and which planes an alias view fetches
//! (paper Eq. 6).

pub mod quant;

pub use quant::*;

/// A storage element format known to the device model.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Fmt {
    /// bfloat16: 1 sign, 8 exponent, 7 mantissa.
    Bf16,
    /// IEEE half: 1 sign, 5 exponent, 10 mantissa.
    Fp16,
    /// FP8 E4M3 (OCP): 1 sign, 4 exponent, 3 mantissa.
    Fp8E4M3,
    /// FP8 E5M2 (OCP): 1 sign, 5 exponent, 2 mantissa.
    Fp8E5M2,
    /// Signed 8-bit integer (per-channel scaled).
    Int8,
    /// Signed 4-bit integer (per-channel scaled, packed 2/byte).
    Int4,
    /// OCP MXFP4: FP4 E2M1 elements with a shared E8M0 scale per 32 elements.
    Mxfp4,
}

impl Fmt {
    /// Total storage bits per element (excluding any shared block scale).
    pub fn bits(self) -> usize {
        match self {
            Fmt::Bf16 | Fmt::Fp16 => 16,
            Fmt::Fp8E4M3 | Fmt::Fp8E5M2 | Fmt::Int8 => 8,
            Fmt::Int4 | Fmt::Mxfp4 => 4,
        }
    }

    /// Bytes per element as an f64 (INT4/MXFP4 are 0.5).
    pub fn bytes(self) -> f64 {
        self.bits() as f64 / 8.0
    }

    /// (sign, exponent, mantissa) bit counts. Integer formats report their
    /// bits as "mantissa" with a 1-bit sign: their MSB planes still behave
    /// like the compressible core (long zero runs from small magnitudes).
    pub fn fields(self) -> (usize, usize, usize) {
        match self {
            Fmt::Bf16 => (1, 8, 7),
            Fmt::Fp16 => (1, 5, 10),
            Fmt::Fp8E4M3 => (1, 4, 3),
            Fmt::Fp8E5M2 => (1, 5, 2),
            Fmt::Int8 => (1, 0, 7),
            Fmt::Int4 => (1, 0, 3),
            Fmt::Mxfp4 => (1, 2, 1),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Fmt::Bf16 => "BF16",
            Fmt::Fp16 => "FP16",
            Fmt::Fp8E4M3 => "FP8",
            Fmt::Fp8E5M2 => "FP8-E5M2",
            Fmt::Int8 => "INT8",
            Fmt::Int4 => "INT4",
            Fmt::Mxfp4 => "MXFP4",
        }
    }

    /// Bit index ranges of the fields within an element word, MSB-first:
    /// sign plane indices, exponent plane indices, mantissa plane indices.
    /// Bit index `bits()-1` is the MSB (sign).
    pub fn plane_roles(self) -> PlaneRoles {
        let (s, e, m) = self.fields();
        let b = self.bits();
        debug_assert_eq!(s + e + m, b);
        PlaneRoles { sign_hi: b - 1, exp_hi: b - 1 - s, exp_lo: m, man_hi: m.saturating_sub(1), total: b }
    }
}

/// Field boundaries in plane-index space (plane i = bit position i).
#[derive(Debug, Clone, Copy)]
pub struct PlaneRoles {
    /// Plane index of the sign bit (the MSB).
    pub sign_hi: usize,
    /// Highest exponent plane index.
    pub exp_hi: usize,
    /// Lowest exponent plane index (= number of mantissa bits).
    pub exp_lo: usize,
    /// Highest mantissa plane index (exp_lo - 1), 0 if no mantissa.
    pub man_hi: usize,
    /// Total planes.
    pub total: usize,
}

impl PlaneRoles {
    /// Role of plane `i` as a short label.
    pub fn role(&self, i: usize) -> &'static str {
        if i == self.sign_hi {
            "sign"
        } else if i >= self.exp_lo && i <= self.exp_hi && self.exp_hi >= self.exp_lo {
            "exp"
        } else {
            "man"
        }
    }
}

// ---------------------------------------------------------------------------
// BF16 conversions
// ---------------------------------------------------------------------------

/// f32 -> BF16 with round-to-nearest-even (matches JAX/XLA semantics).
#[inline]
pub fn bf16_from_f32(x: f32) -> u16 {
    let bits = x.to_bits();
    if x.is_nan() {
        // quiet NaN, preserve sign
        return ((bits >> 16) as u16) | 0x0040;
    }
    let round_bit = 0x0000_8000u32;
    let lsb = (bits >> 16) & 1;
    let rounded = bits.wrapping_add(0x0000_7FFF + lsb);
    let _ = round_bit;
    (rounded >> 16) as u16
}

/// BF16 -> f32 (exact).
#[inline]
pub fn bf16_to_f32(x: u16) -> f32 {
    f32::from_bits((x as u32) << 16)
}

/// Split a BF16 word into (sign, exponent, mantissa).
#[inline]
pub fn bf16_fields(w: u16) -> (u16, u16, u16) {
    ((w >> 15) & 1, (w >> 7) & 0xff, w & 0x7f)
}

/// Assemble a BF16 word from fields.
#[inline]
pub fn bf16_assemble(sign: u16, exp: u16, man: u16) -> u16 {
    ((sign & 1) << 15) | ((exp & 0xff) << 7) | (man & 0x7f)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::props;

    #[test]
    fn bf16_roundtrip_exact_values() {
        for x in [0.0f32, -0.0, 1.0, -1.0, 0.5, 2.0, 65280.0, 2.0f32.powi(-120)] {
            let b = bf16_from_f32(x);
            assert_eq!(bf16_to_f32(b), x, "{x}");
        }
    }

    #[test]
    fn bf16_rtne() {
        // 1.0 + 2^-8 rounds to 1.0 (ties-to-even on the 7-bit mantissa)
        let x = 1.0f32 + 2.0_f32.powi(-8);
        assert_eq!(bf16_to_f32(bf16_from_f32(x)), 1.0);
        // 1.0 + 3*2^-8 rounds up
        let y = 1.0f32 + 3.0 * 2.0_f32.powi(-8);
        assert!(bf16_to_f32(bf16_from_f32(y)) > 1.0);
    }

    #[test]
    fn bf16_nan_inf() {
        assert!(bf16_to_f32(bf16_from_f32(f32::NAN)).is_nan());
        assert_eq!(bf16_to_f32(bf16_from_f32(f32::INFINITY)), f32::INFINITY);
        assert_eq!(bf16_to_f32(bf16_from_f32(f32::NEG_INFINITY)), f32::NEG_INFINITY);
    }

    #[test]
    fn bf16_relative_error_bound() {
        props(21, 2000, |r| {
            let x = (r.normal() * 10f64.powi(r.range(-6, 6) as i32)) as f32;
            let y = bf16_to_f32(bf16_from_f32(x));
            if x != 0.0 && x.is_finite() {
                let rel = ((y - x) / x).abs();
                assert!(rel <= 1.0 / 128.0 + 1e-7, "x={x} y={y} rel={rel}");
            }
        });
    }

    #[test]
    fn fields_assemble_roundtrip() {
        props(22, 2000, |r| {
            let w = r.next_u32() as u16;
            let (s, e, m) = bf16_fields(w);
            assert_eq!(bf16_assemble(s, e, m), w);
        });
    }

    #[test]
    fn plane_roles_bf16() {
        let pr = Fmt::Bf16.plane_roles();
        assert_eq!(pr.role(15), "sign");
        assert_eq!(pr.role(14), "exp");
        assert_eq!(pr.role(7), "exp");
        assert_eq!(pr.role(6), "man");
        assert_eq!(pr.role(0), "man");
    }

    #[test]
    fn fmt_bits() {
        assert_eq!(Fmt::Bf16.bits(), 16);
        assert_eq!(Fmt::Int4.bits(), 4);
        assert_eq!(Fmt::Mxfp4.bytes(), 0.5);
        for f in [Fmt::Bf16, Fmt::Fp16, Fmt::Fp8E4M3, Fmt::Fp8E5M2, Fmt::Int8, Fmt::Int4, Fmt::Mxfp4] {
            let (s, e, m) = f.fields();
            assert_eq!(s + e + m, f.bits(), "{:?}", f);
        }
    }
}
