//! Lossy quantizers for the *offline format choices* the paper layers TRACE
//! under (Table IV "total savings", Figs 17–21 precision bases) and the
//! runtime KV tier policies (Table II).
//!
//! These are simple, well-known schemes (absmax per-channel INT8/INT4,
//! OCP FP8-E4M3 casts, OCP MXFP4 with shared E8M0 block scale). TRACE itself
//! is lossless on top of whichever base the user picked.

use super::{bf16_from_f32, bf16_to_f32};

/// FP8 E4M3 (OCP variant: no infinities, max finite 448, NaN = 0x7f/0xff).
#[inline]
pub fn fp8_e4m3_from_f32(x: f32) -> u8 {
    if x.is_nan() {
        return 0x7f;
    }
    let sign = if x.is_sign_negative() { 0x80u8 } else { 0 };
    let a = x.abs();
    if a == 0.0 {
        return sign;
    }
    if a >= 448.0 {
        return sign | 0x7e; // clamp to max finite (447 behaviour approximated by 448)
    }
    // Decompose into exponent/mantissa with bias 7, 3 mantissa bits.
    let bits = a.to_bits();
    let exp = ((bits >> 23) & 0xff) as i32 - 127;
    let man = bits & 0x7f_ffff;
    if exp < -6 {
        // subnormal range: value = m * 2^-9, m in [0,7]
        let scaled = a / 2f32.powi(-9);
        let m = scaled.round() as u32;
        if m == 0 {
            return sign;
        }
        if m <= 7 {
            return sign | m as u8;
        }
        // rounds up into the normal range
        return sign | 0x08;
    }
    // normal: round mantissa to 3 bits (RTNE)
    let shift = 23 - 3;
    let lsb = (man >> shift) & 1;
    let rounded = man + ((1 << (shift - 1)) - 1) + lsb;
    let mut m3 = rounded >> shift;
    let mut e = exp;
    if m3 >= 8 {
        m3 = 0;
        e += 1;
    }
    if e > 8 {
        return sign | 0x7e;
    }
    let ebits = (e + 7) as u8;
    sign | (ebits << 3) | (m3 as u8)
}

/// FP8 E4M3 -> f32.
#[inline]
pub fn fp8_e4m3_to_f32(b: u8) -> f32 {
    let sign = if b & 0x80 != 0 { -1.0f32 } else { 1.0 };
    let e = ((b >> 3) & 0xf) as i32;
    let m = (b & 0x7) as f32;
    if e == 0xf && (b & 0x7) == 0x7 {
        return f32::NAN;
    }
    if e == 0 {
        sign * m * 2f32.powi(-9)
    } else {
        sign * (1.0 + m / 8.0) * 2f32.powi(e - 7)
    }
}

/// FP4 E2M1 code (0..15) -> value. Magnitudes: 0, .5, 1, 1.5, 2, 3, 4, 6.
#[inline]
pub fn fp4_e2m1_to_f32(code: u8) -> f32 {
    const MAG: [f32; 8] = [0.0, 0.5, 1.0, 1.5, 2.0, 3.0, 4.0, 6.0];
    let v = MAG[(code & 0x7) as usize];
    if code & 0x8 != 0 {
        -v
    } else {
        v
    }
}

/// Nearest FP4 E2M1 code for a value.
#[inline]
pub fn fp4_e2m1_from_f32(x: f32) -> u8 {
    const MAG: [f32; 8] = [0.0, 0.5, 1.0, 1.5, 2.0, 3.0, 4.0, 6.0];
    let sign = if x.is_sign_negative() { 0x8u8 } else { 0 };
    let a = x.abs().min(6.0);
    let mut best = 0usize;
    let mut bd = f32::INFINITY;
    for (i, &m) in MAG.iter().enumerate() {
        let d = (a - m).abs();
        if d < bd {
            bd = d;
            best = i;
        }
    }
    sign | best as u8
}

/// An MXFP4 block: 32 FP4 codes + one shared E8M0 scale (power of two).
#[derive(Debug, Clone, PartialEq)]
pub struct MxBlock {
    /// Shared scale exponent (value = 2^(scale-127)), E8M0.
    pub scale: u8,
    /// 32 FP4 E2M1 codes.
    pub codes: [u8; 32],
}

/// Quantize 32 f32 values to an MXFP4 block (OCP MX spec flow: scale =
/// largest power of two such that max |x|/scale fits in FP4 range).
pub fn mxfp4_quantize(xs: &[f32; 32]) -> MxBlock {
    let amax = xs.iter().fold(0f32, |m, &x| m.max(x.abs()));
    let scale_exp = if amax == 0.0 || !amax.is_finite() {
        0i32
    } else {
        // FP4 max magnitude is 6 = 1.5 * 2^2 -> use exponent of amax minus 2
        (amax.log2().floor() as i32) - 2
    };
    let scale = 2f32.powi(scale_exp);
    let mut codes = [0u8; 32];
    for (i, &x) in xs.iter().enumerate() {
        codes[i] = fp4_e2m1_from_f32(x / scale);
    }
    MxBlock { scale: (scale_exp + 127).clamp(0, 255) as u8, codes }
}

/// Dequantize an MXFP4 block.
pub fn mxfp4_dequantize(b: &MxBlock) -> [f32; 32] {
    let scale = 2f32.powi(b.scale as i32 - 127);
    let mut out = [0f32; 32];
    for i in 0..32 {
        out[i] = fp4_e2m1_to_f32(b.codes[i]) * scale;
    }
    out
}

/// Per-channel absmax INT8 quantization. Returns (codes, scales) where
/// `x ≈ code * scale`, one scale per channel of length `chan_len`.
pub fn int8_quantize(xs: &[f32], chan_len: usize) -> (Vec<i8>, Vec<f32>) {
    assert!(chan_len > 0 && xs.len() % chan_len == 0);
    let mut codes = Vec::with_capacity(xs.len());
    let mut scales = Vec::with_capacity(xs.len() / chan_len);
    for chunk in xs.chunks_exact(chan_len) {
        let amax = chunk.iter().fold(0f32, |m, &x| m.max(x.abs()));
        let scale = if amax == 0.0 { 1.0 } else { amax / 127.0 };
        scales.push(scale);
        for &x in chunk {
            codes.push((x / scale).round().clamp(-127.0, 127.0) as i8);
        }
    }
    (codes, scales)
}

/// Per-channel absmax INT4 quantization (codes in [-7, 7], stored i8).
pub fn int4_quantize(xs: &[f32], chan_len: usize) -> (Vec<i8>, Vec<f32>) {
    assert!(chan_len > 0 && xs.len() % chan_len == 0);
    let mut codes = Vec::with_capacity(xs.len());
    let mut scales = Vec::with_capacity(xs.len() / chan_len);
    for chunk in xs.chunks_exact(chan_len) {
        let amax = chunk.iter().fold(0f32, |m, &x| m.max(x.abs()));
        let scale = if amax == 0.0 { 1.0 } else { amax / 7.0 };
        scales.push(scale);
        for &x in chunk {
            codes.push((x / scale).round().clamp(-7.0, 7.0) as i8);
        }
    }
    (codes, scales)
}

/// Dequantize per-channel integer codes.
pub fn int_dequantize(codes: &[i8], scales: &[f32], chan_len: usize) -> Vec<f32> {
    codes
        .chunks_exact(chan_len)
        .zip(scales)
        .flat_map(|(c, &s)| c.iter().map(move |&q| q as f32 * s))
        .collect()
}

/// Pack INT4 codes two-per-byte (low nibble first), sign-magnitude nibble.
pub fn int4_pack(codes: &[i8]) -> Vec<u8> {
    let nib = |c: i8| -> u8 {
        let mag = c.unsigned_abs().min(7);
        if c < 0 {
            0x8 | mag
        } else {
            mag
        }
    };
    let mut out = Vec::with_capacity(codes.len().div_ceil(2));
    for pair in codes.chunks(2) {
        let lo = nib(pair[0]);
        let hi = if pair.len() > 1 { nib(pair[1]) } else { 0 };
        out.push(lo | (hi << 4));
    }
    out
}

/// Unpack INT4 nibbles back to i8 codes.
pub fn int4_unpack(bytes: &[u8], n: usize) -> Vec<i8> {
    let denib = |n: u8| -> i8 {
        let mag = (n & 0x7) as i8;
        if n & 0x8 != 0 {
            -mag
        } else {
            mag
        }
    };
    let mut out = Vec::with_capacity(n);
    for &b in bytes {
        out.push(denib(b & 0xf));
        if out.len() < n {
            out.push(denib(b >> 4));
        }
        if out.len() >= n {
            break;
        }
    }
    out.truncate(n);
    out
}

/// Truncate a BF16 value to `keep_exp` exponent bits + `keep_man` mantissa
/// bits **as a lossy tier view** (what a plane-aligned reduced-precision
/// fetch returns without guard-plane rounding): drop low mantissa planes.
/// Exponent planes below the keep threshold are also dropped (zeroed), which
/// matches the device behaviour of not fetching those planes.
pub fn bf16_truncate_view(w: u16, keep_man: usize) -> u16 {
    let keep_man = keep_man.min(7);
    let mask: u16 = !(((1u16 << (7 - keep_man)) - 1) & 0x7f);
    w & mask
}

/// BF16 with round-to-nearest applied at a mantissa cut, using `guard`
/// extra mantissa bits (the paper's guard-plane rounding, §III-C).
pub fn bf16_round_view(w: u16, keep_man: usize, guard: usize) -> u16 {
    let keep_man = keep_man.min(7);
    if keep_man == 7 {
        return w;
    }
    let drop = 7 - keep_man;
    let (s, e, m) = super::bf16_fields(w);
    if guard == 0 {
        return super::bf16_assemble(s, e, m & !((1 << drop) - 1));
    }
    // Round to nearest using up to `guard` bits below the cut.
    let g = guard.min(drop);
    let round_add = 1u32 << (drop - 1);
    let visible_mask = !((1u32 << (drop - g)) - 1); // bits the device fetched
    let mv = (m as u32) & visible_mask;
    let mut rounded = (mv + round_add) >> drop;
    let mut exp = e as u32;
    if rounded >= (1 << keep_man.max(0)) && keep_man > 0 && rounded >= (1 << keep_man) {
        rounded = 0;
        exp += 1;
    } else if keep_man == 0 && rounded >= 1 {
        rounded = 0;
        exp += 1;
    }
    if exp > 0xff {
        exp = 0xff;
        rounded = 0;
    }
    super::bf16_assemble(s, exp as u16, (rounded << drop) as u16)
}

/// Mean squared error between two f32 slices.
pub fn mse(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    if a.is_empty() {
        return 0.0;
    }
    a.iter().zip(b).map(|(&x, &y)| ((x - y) as f64).powi(2)).sum::<f64>() / a.len() as f64
}

/// Quantize f32s through BF16 (the baseline lossless storage format).
pub fn to_bf16_f32(xs: &[f32]) -> Vec<f32> {
    xs.iter().map(|&x| bf16_to_f32(bf16_from_f32(x))).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::props;
    use crate::util::Rng;

    #[test]
    fn fp8_exact_codes_roundtrip() {
        // every FP8 code except NaN must roundtrip exactly through f32
        for b in 0u8..=255 {
            let x = fp8_e4m3_to_f32(b);
            if x.is_nan() {
                continue;
            }
            let b2 = fp8_e4m3_from_f32(x);
            assert_eq!(fp8_e4m3_to_f32(b2), x, "code {b:#x}");
        }
    }

    #[test]
    fn fp8_clamps() {
        assert_eq!(fp8_e4m3_to_f32(fp8_e4m3_from_f32(1e9)), 448.0);
        assert_eq!(fp8_e4m3_to_f32(fp8_e4m3_from_f32(-1e9)), -448.0);
    }

    #[test]
    fn fp8_relative_error() {
        props(31, 2000, |r| {
            let x = (r.normal() * 10f64.powi(r.range(-2, 2) as i32)) as f32;
            let y = fp8_e4m3_to_f32(fp8_e4m3_from_f32(x));
            if x.abs() > 2f32.powi(-6) && x.abs() < 400.0 {
                let rel = ((y - x) / x).abs();
                assert!(rel <= 1.0 / 16.0 + 1e-6, "x={x} y={y}");
            }
        });
    }

    #[test]
    fn fp4_codes() {
        assert_eq!(fp4_e2m1_to_f32(0), 0.0);
        assert_eq!(fp4_e2m1_to_f32(0x7), 6.0);
        assert_eq!(fp4_e2m1_to_f32(0xf), -6.0);
        for c in 0u8..16 {
            let v = fp4_e2m1_to_f32(c);
            let c2 = fp4_e2m1_from_f32(v);
            assert_eq!(fp4_e2m1_to_f32(c2), v);
        }
    }

    #[test]
    fn mxfp4_bounded_error() {
        props(32, 300, |r| {
            let mut xs = [0f32; 32];
            let scale = 10f64.powi(r.range(-3, 3) as i32);
            for x in xs.iter_mut() {
                *x = (r.normal() * scale) as f32;
            }
            let blk = mxfp4_quantize(&xs);
            let ys = mxfp4_dequantize(&blk);
            let amax = xs.iter().fold(0f32, |m, &x| m.max(x.abs()));
            for (x, y) in xs.iter().zip(ys.iter()) {
                // FP4 relative step within a block is at most amax/4-ish
                assert!((x - y).abs() <= amax * 0.26 + 1e-12, "x={x} y={y} amax={amax}");
            }
        });
    }

    #[test]
    fn int8_int4_roundtrip_error() {
        let mut r = Rng::new(33);
        let xs: Vec<f32> = (0..256).map(|_| r.normal() as f32).collect();
        let (c8, s8) = int8_quantize(&xs, 64);
        let y8 = int_dequantize(&c8, &s8, 64);
        assert!(mse(&xs, &y8) < 1e-4);
        let (c4, s4) = int4_quantize(&xs, 64);
        let y4 = int_dequantize(&c4, &s4, 64);
        assert!(mse(&xs, &y4) < 0.05);
        assert!(mse(&xs, &y4) > mse(&xs, &y8));
    }

    #[test]
    fn int4_pack_roundtrip() {
        props(34, 500, |r| {
            let n = 1 + r.below(99);
            let codes: Vec<i8> = (0..n).map(|_| r.range(-7, 7) as i8).collect();
            let packed = int4_pack(&codes);
            assert_eq!(packed.len(), n.div_ceil(2));
            assert_eq!(int4_unpack(&packed, n), codes);
        });
    }

    #[test]
    fn truncate_view_monotone() {
        let w = bf16_from_f32(1.2345);
        let full = bf16_to_f32(w);
        let mut prev_err = 0.0f32;
        for keep in (0..=7).rev() {
            let t = bf16_to_f32(bf16_truncate_view(w, keep));
            let err = (t - full).abs();
            assert!(err >= prev_err - 1e-9);
            prev_err = err;
        }
        assert_eq!(bf16_truncate_view(w, 7), w);
    }

    #[test]
    fn guard_rounding_improves_on_truncation() {
        // statistically, round-to-nearest at the cut must beat truncation
        let mut r = Rng::new(35);
        let xs: Vec<f32> = (0..4096).map(|_| (r.normal() * 3.0) as f32).collect();
        for keep in [2usize, 3, 4] {
            let mut trunc_err = 0.0;
            let mut round_err = 0.0;
            for &x in &xs {
                let w = bf16_from_f32(x);
                let full = bf16_to_f32(w);
                let t = bf16_to_f32(bf16_truncate_view(w, keep));
                let g = bf16_to_f32(bf16_round_view(w, keep, 2));
                trunc_err += ((t - full) as f64).powi(2);
                round_err += ((g - full) as f64).powi(2);
            }
            assert!(
                round_err < trunc_err,
                "keep={keep} round_err={round_err} trunc_err={trunc_err}"
            );
        }
    }

    #[test]
    fn round_view_full_precision_identity() {
        props(36, 500, |r| {
            let w = r.next_u32() as u16;
            assert_eq!(bf16_round_view(w, 7, 2), w);
        });
    }
}
