//! Fig. 2 — KV is structurally smoother along channels than across tokens.
//!
//! Quantified as lag-1 autocorrelation and mean absolute difference along
//! each axis of the calibrated KV (the visualization's statistics), plus
//! the byte-entropy drop from the TRACE transform (the Fig. 7 claim).

use trace_cxl::bitplane::{transpose_to_planes, KvTransform, KvWindow};
use trace_cxl::formats::bf16_to_f32;
use trace_cxl::gen::KvGen;
use trace_cxl::util::bytes::u16s_to_bytes;
use trace_cxl::util::stats::{autocorr1, byte_entropy};
use trace_cxl::util::Rng;

fn main() {
    let mut rng = Rng::new(0xF2);
    let (tokens, channels) = (256usize, 128usize);
    let kv = KvGen::default_for(channels).generate(&mut rng, tokens);
    let f: Vec<f32> = kv.iter().map(|&w| bf16_to_f32(w)).collect();

    // autocorrelation along tokens within a channel vs along channels
    let mut ac_chan = 0.0;
    let mut ad_chan = 0.0;
    for j in 0..channels {
        let series: Vec<f64> = (0..tokens).map(|t| f[t * channels + j] as f64).collect();
        ac_chan += autocorr1(&series);
        ad_chan += series.windows(2).map(|w| (w[1] - w[0]).abs()).sum::<f64>() / (tokens - 1) as f64;
    }
    ac_chan /= channels as f64;
    ad_chan /= channels as f64;

    let mut ac_tok = 0.0;
    let mut ad_tok = 0.0;
    for t in 0..tokens {
        let row: Vec<f64> = (0..channels).map(|j| f[t * channels + j] as f64).collect();
        ac_tok += autocorr1(&row);
        ad_tok += row.windows(2).map(|w| (w[1] - w[0]).abs()).sum::<f64>() / (channels - 1) as f64;
    }
    ac_tok /= tokens as f64;
    ad_tok /= tokens as f64;

    println!("# Fig 2: KV smoothness by axis (LLaMA-shaped KV, layer-0 statistics)");
    println!("{:<28} {:>14} {:>14}", "", "along channel", "across tokens");
    println!("{:<28} {:>14.3} {:>14.3}", "lag-1 autocorrelation", ac_chan, ac_tok);
    println!("{:<28} {:>14.3} {:>14.3}", "mean |delta|", ad_chan, ad_tok);
    assert!(ac_chan > ac_tok + 0.3, "channel axis must be much smoother");
    assert!(ad_chan < ad_tok, "smaller steps along the channel axis");

    // entropy evidence for the transform (Fig. 7)
    let raw_h = byte_entropy(&u16s_to_bytes(&kv));
    let t = KvTransform::forward(&kv, KvWindow::new(tokens, channels));
    let planes = transpose_to_planes(&t.words, 16);
    let plane_h = byte_entropy(&planes);
    println!("\nbyte entropy: word-major stream {raw_h:.2} b/B -> TRACE plane streams {plane_h:.2} b/B");
    assert!(plane_h < raw_h - 0.5);
}
