//! §NMC — near-memory gather/reduce offload at long context, model-time
//! tok/s and host-link read traffic.
//!
//! Runs the full engine (mock backend, TRACE device, 24 shards) twice at
//! a 128k-token spilled context — fetch planner off and on — and reports
//! decode throughput plus link traffic. Gates (ISSUE 8 acceptance):
//!
//! * tokens are bit-identical offload-on vs. offload-off;
//! * with spill active and per-page selectivity < 25%, offload-on
//!   model-time tok/s is ≥ 2× offload-off;
//! * host-link read bytes shrink at least in proportion to the
//!   selectivity ratio (within a 15% payload-overhead allowance for the
//!   row indices and query upload).
//!
//! `prefill_ns_per_token` is zeroed so model time is decode-dominated:
//! the planner only acts on decode-step fetches, and a fixed multi-ms
//! prefill charge would mask the decode speedup this figure measures.
//!
//! Run: `cargo bench --bench fig_nmc`

use std::collections::BTreeMap;

use trace_cxl::coordinator::{Engine, EngineConfig};
use trace_cxl::cxl::{DeviceStats, MemDevice};
use trace_cxl::runtime::{MockBackend, ModelDims};
use trace_cxl::tier::PAGE_TOKENS;
use trace_cxl::util::json::Json;

/// 128k-token context: 8192 spilled pages of 4 KB (el = 128 → one page
/// is exactly one 4 KB device block).
const CTX: usize = 131072;
const DECODE: usize = 24;

fn dims() -> ModelDims {
    ModelDims {
        layers: 4,
        batch: 1,
        t_max: CTX + DECODE + 8,
        t_prompt: CTX,
        d_model: 16,
        heads: 4,
        head_dim: 4,
        ffn: 32,
        vocab: 64,
    }
}

struct Run {
    tokens: Vec<Vec<u32>>,
    stats: DeviceStats,
    model_ns: f64,
    generated: u64,
    spilled: u64,
    offloads: u64,
    saved: u64,
}

fn run(nmc: bool) -> Run {
    let mut e = Engine::new(
        MockBackend::new(dims(), 42),
        EngineConfig {
            hbm_kv_bytes: 0, // the whole context spills to the device
            shards: 24,
            decode_cache_blocks: 16384, // hold every page (wall-clock only)
            prefill_ns_per_token: 0.0,
            nmc,
            ..Default::default()
        },
    );
    let prompt: Vec<u32> = (0..CTX).map(|i| (i % 63) as u32 + 1).collect();
    e.submit(prompt, DECODE);
    e.run_to_completion(200).unwrap();
    let mut rs = e.take_responses();
    rs.sort_by_key(|r| r.id);
    Run {
        tokens: rs.into_iter().map(|r| r.tokens).collect(),
        stats: e.device.stats(),
        model_ns: e.metrics.model_ns,
        generated: e.metrics.tokens_generated,
        spilled: e.metrics.pages_spilled,
        offloads: e.metrics.nmc_offloads,
        saved: e.metrics.link_bytes_saved,
    }
}

fn main() {
    let cfg = EngineConfig::default();
    let sel = (cfg.nmc_topk_frac * PAGE_TOKENS as f64).ceil() / PAGE_TOKENS as f64;
    println!("# fig_nmc — near-memory gather/reduce offload, 128k-token spilled context");
    println!(
        "# mock backend, TRACE device, 24 shards, top-k frac {} (selectivity {:.3})\n",
        cfg.nmc_topk_frac, sel
    );
    assert!(sel < 0.25, "gate regime requires selectivity < 25%");

    let off = run(false);
    let on = run(true);

    assert_eq!(off.tokens, on.tokens, "offload must not change tokens");
    assert!(off.spilled > 0, "gate regime requires spill to be active");
    assert_eq!(off.offloads, 0);
    assert!(on.offloads > 0, "planner must offload at this context length");

    let tok_s = |r: &Run| r.generated as f64 / (r.model_ns * 1e-9);
    println!(
        "{:<10} {:>12} {:>12} {:>14} {:>10} {:>12}",
        "planner", "model µs", "tok/s", "link rd MB", "offloads", "saved MB"
    );
    for (label, r) in [("off", &off), ("on", &on)] {
        println!(
            "{:<10} {:>12.1} {:>12.0} {:>14.2} {:>10} {:>12.2}",
            label,
            r.model_ns * 1e-3,
            tok_s(r),
            r.stats.link_bytes_out as f64 / 1e6,
            r.offloads,
            r.saved as f64 / 1e6,
        );
    }

    let speedup = tok_s(&on) / tok_s(&off);
    let link_ratio = on.stats.link_bytes_out as f64 / off.stats.link_bytes_out as f64;
    println!("\nspeedup {speedup:.2}x, link-read ratio {link_ratio:.3} (selectivity {sel:.3})");

    assert!(
        speedup >= 2.0,
        "offload-on decode must be ≥ 2x offload-off in model time (got {speedup:.2}x)"
    );
    assert!(
        link_ratio <= sel * 1.15,
        "host-link reads must shrink at least with selectivity \
         (ratio {link_ratio:.3} vs budget {:.3})",
        sel * 1.15
    );
    assert!(on.stats.nmc_bytes_scanned > 0, "device-side scans must be accounted");
    assert!(
        on.saved >= off.stats.link_bytes_out.saturating_sub(on.stats.link_bytes_out),
        "banked savings must cover the observed link delta"
    );

    append_history(&off, &on, speedup, link_ratio);
    println!("OK: near-memory offload is bit-identical, ≥2x faster, and link-lean");
}

/// Append this run's tok/s + GB/s numbers to the shared per-SHA perf
/// history (`BENCH_hotpaths.json`, same append-only array
/// `perf_hotpaths` maintains), so the offload trajectory is diffable
/// across PRs alongside the hot-path kernels.
fn append_history(off: &Run, on: &Run, speedup: f64, link_ratio: f64) {
    let path = "BENCH_hotpaths.json";
    let mut hist = match std::fs::read_to_string(path) {
        Ok(text) => match Json::parse(&text) {
            Ok(Json::Arr(entries)) => entries,
            _ => Vec::new(),
        },
        Err(_) => Vec::new(),
    };
    let tok_s = |r: &Run| r.generated as f64 / (r.model_ns * 1e-9);
    let mut sections = BTreeMap::new();
    sections.insert("nmc_tok_s_off".to_string(), Json::Num(tok_s(off)));
    sections.insert("nmc_tok_s_on".to_string(), Json::Num(tok_s(on)));
    sections.insert("nmc_speedup".to_string(), Json::Num(speedup));
    sections.insert("nmc_link_ratio".to_string(), Json::Num(link_ratio));
    sections.insert(
        "nmc_scan_gbps".to_string(),
        Json::Num(on.stats.nmc_bytes_scanned as f64 / on.model_ns),
    );
    let mut entry = BTreeMap::new();
    entry.insert("sha".to_string(), Json::Str(git_sha()));
    entry.insert("bench".to_string(), Json::Str("fig_nmc".to_string()));
    entry.insert("sections".to_string(), Json::Obj(sections));
    hist.push(Json::Obj(entry));
    let n = hist.len();
    std::fs::write(path, format!("{}\n", Json::Arr(hist))).expect("write bench json");
    println!("wrote {path} ({n} history entries)");
}

/// History key: CI's commit SHA when present, else local git HEAD.
fn git_sha() -> String {
    if let Ok(sha) = std::env::var("GITHUB_SHA") {
        return sha;
    }
    std::process::Command::new("git")
        .args(["rev-parse", "--short", "HEAD"])
        .output()
        .ok()
        .filter(|o| o.status.success())
        .and_then(|o| String::from_utf8(o.stdout).ok())
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .unwrap_or_else(|| "unknown".to_string())
}
