//! Fig. 22 — controller load-to-use pipeline timing breakdown
//! (metadata-cache hit): 71 / 84 / 89 cycles for Plain / GComp / TRACE,
//! plus the metadata-miss case (one extra DRAM access window).

use trace_cxl::cxl::{latency, LatencyCase};

fn main() {
    println!("# Fig 22: load-to-use pipeline breakdown (cycles @2 GHz; metadata-cache hit)");
    println!(
        "{:<16} {:>4} {:>4} {:>4} {:>6} {:>5} {:>6} {:>7} {:>6} {:>8} {:>8}",
        "design", "F", "M", "S", "tRCD", "tCL", "B", "codec", "miss", "total", "ns"
    );
    let rows = [
        ("CXL-Plain", LatencyCase::Plain),
        ("CXL-GComp", LatencyCase::GComp { metadata_hit: true }),
        ("TRACE", LatencyCase::Trace { metadata_hit: true, ratio: 1.5, bypass: false }),
        ("TRACE (miss)", LatencyCase::Trace { metadata_hit: false, ratio: 1.5, bypass: false }),
    ];
    let mut totals = Vec::new();
    for (name, case) in rows {
        let b = latency(case);
        println!(
            "{:<16} {:>4} {:>4} {:>4} {:>6} {:>5} {:>6} {:>7} {:>6} {:>8} {:>8.1}",
            name, b.frontend, b.metadata, b.scheduler, b.trcd, b.tcl, b.burst, b.codec,
            b.meta_miss, b.total_cycles(), b.total_ns()
        );
        totals.push(b.total_cycles());
    }
    assert_eq!(totals[0], 71);
    assert_eq!(totals[1], 84);
    assert_eq!(totals[2], 89);
    assert!(totals[3] > totals[2] + 40, "miss adds ~one DRAM window");
    println!("\npaper: 71 (35.5 ns) / 84 (42.0 ns) / 89 (44.5 ns); codec streams overlapped with DRAM");
}
