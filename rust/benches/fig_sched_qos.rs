//! §Scheduling — QoS under overload: FCFS vs SJF vs PriorityClass.
//!
//! Replays one open-loop Poisson arrival trace (≥2× overload, mixed
//! interactive/batch traffic) through the serving engine under each
//! scheduling policy and reports per-class TTFT, queue delay, preemption
//! counts, and aggregate model-time throughput.
//!
//! Gates (ISSUE 4 acceptance):
//!
//! * the trace is genuinely overloaded: serving it takes ≥2× the arrival
//!   window under FCFS;
//! * `PriorityClass` strictly improves interactive p99 TTFT over `Fcfs`;
//! * aggregate model-time tok/s under `PriorityClass` stays within 10%
//!   of `Fcfs` (preemption save/restore overhead is bounded);
//! * every policy finishes every request and drains the device.
//!
//! Run: `cargo bench --bench fig_sched_qos`

use trace_cxl::coordinator::{Engine, EngineConfig, SchedKind, SlaClass};
use trace_cxl::cxl::MemDevice;
use trace_cxl::gen::RequestGen;
use trace_cxl::runtime::{MockBackend, ModelDims};
use trace_cxl::util::Rng;

fn dims() -> ModelDims {
    ModelDims {
        layers: 2,
        batch: 4,
        t_max: 512,
        t_prompt: 8,
        d_model: 32,
        heads: 2,
        head_dim: 8,
        ffn: 64,
        vocab: 128,
    }
}

struct Arrival {
    prompt: Vec<u32>,
    decode: usize,
    at_ns: f64,
    sla: SlaClass,
}

/// One Poisson trace, shared by every policy run: ~40% interactive (short
/// decodes) and ~60% batch (long decodes), arriving fast enough to
/// overload the 4-slot engine at least 2× (the batch-heavy mix keeps the
/// drain tail slot-saturated, so preemption's throughput cost stays well
/// inside the 10% gate).
fn trace(n: usize) -> Vec<Arrival> {
    let mut rng = Rng::new(1234);
    let gen = RequestGen::new(250_000.0, 2, dims().t_prompt, 32, dims().vocab as u32);
    gen.generate(&mut rng, n)
        .into_iter()
        .map(|r| {
            let interactive = rng.chance(0.4);
            Arrival {
                prompt: r.prompt,
                decode: if interactive { 8 } else { 64 },
                at_ns: r.arrival_ns(),
                sla: if interactive { SlaClass::Interactive } else { SlaClass::Batch },
            }
        })
        .collect()
}

struct Run {
    kind: SchedKind,
    tokens: u64,
    model_ns: f64,
    preemptions: u64,
    resumes: u64,
    int_ttft_p50: f64,
    int_ttft_p99: f64,
    batch_ttft_p99: f64,
    queue_p99: f64,
}

fn run(kind: SchedKind, arrivals: &[Arrival]) -> Run {
    let mut e = Engine::new(
        MockBackend::new(dims(), 42),
        EngineConfig { hbm_kv_bytes: 4096, sched: kind, ..Default::default() },
    );
    for a in arrivals {
        e.submit_at(a.prompt.clone(), a.decode, a.at_ns, a.sla);
    }
    e.run_to_completion(500_000).unwrap();
    assert_eq!(
        e.metrics.requests_finished as usize,
        arrivals.len(),
        "{}: every request must finish",
        kind.name()
    );
    assert_eq!(e.device.len(), 0, "{}: device must drain", kind.name());
    let int = e.metrics.ttft_class(SlaClass::Interactive);
    let bat = e.metrics.ttft_class(SlaClass::Batch);
    assert!(int.n > 0 && bat.n > 0, "trace must exercise both QoS classes");
    Run {
        kind,
        tokens: e.metrics.tokens_generated,
        model_ns: e.metrics.model_ns,
        preemptions: e.metrics.preemptions,
        resumes: e.metrics.resumes,
        int_ttft_p50: int.p50,
        int_ttft_p99: int.p99,
        batch_ttft_p99: bat.p99,
        queue_p99: e.metrics.queue_delay().p99,
    }
}

fn main() {
    println!("# fig_sched_qos — scheduling policies under ≥2x overload");
    let arrivals = trace(60);
    let span_ns = arrivals.iter().map(|a| a.at_ns).fold(0.0f64, f64::max);
    let offered: u64 = arrivals.iter().map(|a| a.decode as u64).sum();
    let n_int = arrivals.iter().filter(|a| a.sla == SlaClass::Interactive).count();
    println!(
        "# {} requests ({} interactive / {} batch), {} decode tokens offered over {:.1} us\n",
        arrivals.len(),
        n_int,
        arrivals.len() - n_int,
        offered,
        span_ns / 1000.0
    );
    println!(
        "{:<10} {:>10} {:>9} {:>8} {:>14} {:>14} {:>14} {:>13}",
        "policy",
        "tok/s",
        "preempt",
        "resume",
        "int TTFT p50",
        "int TTFT p99",
        "bat TTFT p99",
        "queue p99"
    );

    let mut runs = Vec::new();
    for kind in [SchedKind::Fcfs, SchedKind::Sjf, SchedKind::Priority] {
        let r = run(kind, &arrivals);
        println!(
            "{:<10} {:>10.0} {:>9} {:>8} {:>11.1} us {:>11.1} us {:>11.1} us {:>10.1} us",
            r.kind.name(),
            r.tokens as f64 / (r.model_ns * 1e-9),
            r.preemptions,
            r.resumes,
            r.int_ttft_p50 / 1000.0,
            r.int_ttft_p99 / 1000.0,
            r.batch_ttft_p99 / 1000.0,
            r.queue_p99 / 1000.0,
        );
        runs.push(r);
    }
    let fcfs = &runs[0];
    let prio = &runs[2];

    // gate 1: the trace is a genuine overload for the engine
    let overload = fcfs.model_ns / span_ns;
    println!("\n# overload factor (FCFS service time / arrival window): {overload:.2}x");
    assert!(overload >= 2.0, "trace must overload the engine >=2x, got {overload:.2}x");

    // gate 2: priority strictly improves the interactive tail
    assert!(
        prio.int_ttft_p99 < fcfs.int_ttft_p99,
        "PriorityClass must cut interactive p99 TTFT (priority {:.1} us vs fcfs {:.1} us)",
        prio.int_ttft_p99 / 1000.0,
        fcfs.int_ttft_p99 / 1000.0
    );

    // gate 3: the throughput cost of preemption stays bounded
    assert_eq!(fcfs.tokens, prio.tokens, "same offered work must yield the same tokens");
    let fcfs_tps = fcfs.tokens as f64 / (fcfs.model_ns * 1e-9);
    let prio_tps = prio.tokens as f64 / (prio.model_ns * 1e-9);
    assert!(
        prio_tps >= 0.90 * fcfs_tps,
        "PriorityClass must keep aggregate tok/s within 10% of FCFS \
         (priority {prio_tps:.0} vs fcfs {fcfs_tps:.0})"
    );
    assert!(prio.preemptions >= 1, "overload with QoS tiers must exercise preemption");
    assert_eq!(prio.resumes, prio.preemptions, "every victim resumes");

    println!(
        "\nOK: interactive p99 TTFT {:.1}x better under PriorityClass at {:.1}% of FCFS throughput",
        fcfs.int_ttft_p99 / prio.int_ttft_p99,
        100.0 * prio_tps / fcfs_tps
    );
}
