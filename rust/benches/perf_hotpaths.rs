//! §Perf — wall-clock microbenchmarks of the L3 hot paths (the
//! criterion-style harness; criterion itself is not in the offline vendor
//! set, so this uses a measured-loop harness with warmup).
//!
//! Targets (DESIGN.md §6): bit-transpose ≥ 1 GB/s/core, LZ4 compress ≥
//! 300 MB/s/core, KV transform ≥ 500 MB/s, DRAM sim ≥ 10 M cmds/s,
//! device write path ≥ 100 MB/s with ZSTD enabled.
//!
//! PR-5 gates (docs/PERF.md):
//! * **zero-alloc decode** — a steady-state single-block decode through
//!   [`BlockScratch`] performs zero heap allocations, proven by a
//!   counting global allocator (exact, not sampled).
//! * **batch spill-decode ≥ 2×** — the batched 4-shard spill-decode
//!   workload (pool 4 + decoded-plane cache + scratch) beats the serial
//!   cache-off path (the PR-4 baseline) by ≥ 2× wall-clock.
//!
//! PR-7 gates (docs/PERF.md §codec lanes + vector kernels):
//! * **RLE vector decompress ≥ 3×** its byte/slice scalar predecessor and
//!   **Huffman table decoder ≥ 2×** the bit-at-a-time reference, single
//!   thread, on the workload shapes the planes actually produce.
//! * **4 codec lanes ≥ 2×** lower single-block 16-plane decode wall time
//!   than 1 lane, and the lanes-on decode stays zero-allocation.
//!
//! Flags: `--quick` shrinks the measure window and reports (instead of
//! asserting) every wall-clock threshold — absolute rates AND the relative
//! speedup ratios, since a shared CI runner can stall either side of a
//! ratio — while keeping the fully deterministic allocation-count gates.
//! Every section's throughput lands in `BENCH_hotpaths.json` (GB/s +
//! ns/op): an append-only history array with one entry per run, keyed by
//! git SHA, so the perf trajectory is diffable across PRs (the committed
//! seed entry is the baseline).

use std::alloc::{GlobalAlloc, Layout, System};
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

use trace_cxl::bitplane::{
    transpose_from_planes, transpose_to_planes, BlockScratch, DeviceBlock, KvTransform, KvWindow,
};
use trace_cxl::codec::{self, compress_best, CodecKind, CodecPolicy};
use trace_cxl::coordinator::{Engine, EngineConfig};
use trace_cxl::cxl::{
    CxlDevice, Design, MemDevice, ShardedDevice, SubmissionQueue, Transaction, STRIPE_BYTES,
};
use trace_cxl::dram::{AddrMap, DramConfig, DramSim, EnergyParams, Request};
use trace_cxl::gen::KvGen;
use trace_cxl::runtime::{MockBackend, ModelDims};
use trace_cxl::util::json::Json;
use trace_cxl::util::{LanePool, Rng};

/// Counting allocator: every `alloc`/`realloc`/`alloc_zeroed` bumps a
/// global counter, so "zero allocations" is provable, not inferred.
struct CountingAlloc;

static ALLOC_COUNT: AtomicU64 = AtomicU64::new(0);

// SAFETY: pure pass-through to `System` plus a relaxed counter bump; every
// contract (layout validity, pointer provenance) is forwarded unchanged
unsafe impl GlobalAlloc for CountingAlloc {
    // SAFETY: caller upholds `GlobalAlloc::alloc`'s layout contract
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_COUNT.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }
    // SAFETY: caller passes a pointer previously returned by this allocator
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
    // SAFETY: caller upholds `GlobalAlloc::realloc`'s contract
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOC_COUNT.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
    // SAFETY: caller upholds `GlobalAlloc::alloc_zeroed`'s contract
    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOC_COUNT.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn allocations() -> u64 {
    ALLOC_COUNT.load(Ordering::Relaxed)
}

/// One report row: throughput + per-iteration latency.
struct Report {
    sections: BTreeMap<String, Json>,
    measure_secs: f64,
}

impl Report {
    fn record(&mut self, name: &str, rate_units_per_s: f64, units_per_iter: usize) {
        let mut o = BTreeMap::new();
        o.insert("gbps".to_string(), Json::Num(rate_units_per_s / 1e9));
        o.insert(
            "ns_per_op".to_string(),
            Json::Num(if rate_units_per_s > 0.0 {
                units_per_iter as f64 / rate_units_per_s * 1e9
            } else {
                0.0
            }),
        );
        self.sections.insert(name.to_string(), Json::Obj(o));
    }

    fn record_raw(&mut self, name: &str, value: f64) {
        self.sections.insert(name.to_string(), Json::Num(value));
    }

    /// Append this run to the history file: `BENCH_hotpaths.json` is an
    /// append-only array of per-run entries keyed by git SHA, so every
    /// section's GB/s is comparable across PRs. A legacy single-object
    /// file (the pre-history format) or a corrupt file starts a fresh
    /// history at this run rather than guessing at its shape.
    fn write(&self, path: &str) {
        let mut hist = match std::fs::read_to_string(path) {
            Ok(text) => match Json::parse(&text) {
                Ok(Json::Arr(entries)) => entries,
                _ => Vec::new(),
            },
            Err(_) => Vec::new(),
        };
        let mut entry = BTreeMap::new();
        entry.insert("sha".to_string(), Json::Str(git_sha()));
        entry.insert("measure_secs".to_string(), Json::Num(self.measure_secs));
        entry.insert("sections".to_string(), Json::Obj(self.sections.clone()));
        hist.push(Json::Obj(entry));
        let n = hist.len();
        let doc = Json::Arr(hist);
        std::fs::write(path, format!("{doc}\n")).expect("write bench json");
        println!("\nwrote {path} ({n} history entries)");
    }
}

/// History key for one bench run: CI's commit SHA when present, else the
/// local git HEAD, else "unknown" (running outside a checkout).
fn git_sha() -> String {
    if let Ok(sha) = std::env::var("GITHUB_SHA") {
        return sha;
    }
    std::process::Command::new("git")
        .args(["rev-parse", "--short", "HEAD"])
        .output()
        .ok()
        .filter(|o| o.status.success())
        .and_then(|o| String::from_utf8(o.stdout).ok())
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .unwrap_or_else(|| "unknown".to_string())
}

fn bench<F: FnMut() -> usize>(r: &mut Report, name: &str, bytes_label: &str, mut f: F) -> f64 {
    // warmup
    let mut processed = 0usize;
    for _ in 0..2 {
        processed = f();
    }
    let t0 = Instant::now();
    let mut total = 0usize;
    let mut iters = 0;
    while t0.elapsed().as_secs_f64() < r.measure_secs {
        total += f();
        iters += 1;
    }
    let dt = t0.elapsed().as_secs_f64();
    let rate = total as f64 / dt;
    println!(
        "{name:<28} {:>10.1} M{bytes_label}/s   ({iters} iters, {processed} per iter)",
        rate / 1e6
    );
    r.record(name, rate, processed);
    rate
}

/// The batched 4-shard spill-decode workload: the shape of one engine
/// decode step under heavy spill — every block of the working set fetched
/// as one submission batch, repeatedly (the steady-state refetch of
/// tier-resident KV). Returns seconds per batch.
fn spill_decode_workload(pool: usize, cache: usize, batches: usize) -> f64 {
    let mut rng = Rng::new(0xBA7C);
    let kv = KvGen::default_for(64).generate(&mut rng, 32);
    let mut dev = ShardedDevice::new(4, Design::Trace, CodecPolicy::FastBest);
    dev.set_pool(pool);
    dev.set_decode_cache(cache);
    let blocks = 32u64;
    let mut sq = SubmissionQueue::new();
    for b in 0..blocks {
        sq.submit(Transaction::WriteKv {
            block_addr: b * STRIPE_BYTES,
            words: kv.clone(),
            window: KvWindow::new(32, 64),
        });
    }
    for c in dev.drain(&mut sq) {
        c.result.unwrap();
    }
    // warmup round (fills the decode cache when enabled)
    let round = |dev: &mut ShardedDevice| {
        let mut sq = SubmissionQueue::new();
        for b in 0..blocks {
            sq.submit(Transaction::ReadFull { block_addr: b * STRIPE_BYTES });
        }
        for c in dev.drain(&mut sq) {
            std::hint::black_box(c.result.unwrap());
        }
    };
    round(&mut dev);
    let t0 = Instant::now();
    for _ in 0..batches {
        round(&mut dev);
    }
    t0.elapsed().as_secs_f64() / batches as f64
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let mut report =
        Report { sections: BTreeMap::new(), measure_secs: if quick { 0.06 } else { 0.5 } };
    let gate = |ok: bool, msg: &str| {
        if quick {
            if !ok {
                println!("  (quick mode: timing threshold skipped — {msg})");
            }
        } else {
            assert!(ok, "{msg}");
        }
    };
    let mut rng = Rng::new(0x9E7F);
    println!("# Perf hot paths (single core{})", if quick { ", --quick" } else { "" });

    // bit transpose
    let words: Vec<u16> = (0..32 * 2048).map(|_| rng.next_u32() as u16).collect();
    let n_bytes = words.len() * 2;
    // Target revised after the §Perf pass (EXPERIMENTS.md): scalar SWAR
    // roofline on this box is ~0.7 GB/s; 0.5 GB/s is the regression gate.
    let r = bench(&mut report, "bit transpose (to planes)", "B", || {
        std::hint::black_box(transpose_to_planes(&words, 16));
        n_bytes
    });
    gate(r > 250e6, &format!("transpose gate 250 MB/s, got {:.0} MB/s", r / 1e6));

    let planes = transpose_to_planes(&words, 16);
    let r = bench(&mut report, "bit transpose (from planes)", "B", || {
        std::hint::black_box(transpose_from_planes(&planes, words.len(), 16, 0xffff));
        n_bytes
    });
    gate(r > 150e6, &format!("inverse transpose gate 150 MB/s, got {:.0} MB/s", r / 1e6));

    // KV transform
    let kv = KvGen::default_for(128).generate(&mut rng, 512);
    let kvb = kv.len() * 2;
    bench(&mut report, "KV transform (fwd)", "B", || {
        std::hint::black_box(KvTransform::forward(&kv, KvWindow::new(512, 128)));
        kvb
    });

    // codecs on a 64 KB plane-like buffer
    let mut mixed = vec![0u8; 65536];
    for (i, b) in mixed.iter_mut().enumerate() {
        *b = if i % 7 == 0 { (i / 97) as u8 } else { 0 };
    }
    let r = bench(&mut report, "LZ4 compress (sparse)", "B", || {
        std::hint::black_box(codec::compress(CodecKind::Lz4, &mixed));
        mixed.len()
    });
    gate(r > 150e6, &format!("LZ4 target 150 MB/s, got {:.0} MB/s", r / 1e6));
    let enc = codec::compress(CodecKind::Lz4, &mixed);
    bench(&mut report, "LZ4 decompress", "B", || {
        std::hint::black_box(codec::decompress(CodecKind::Lz4, &enc, mixed.len()).unwrap());
        mixed.len()
    });
    // the scratch path must not be slower than the allocating path
    let mut lz4_out = vec![0u8; mixed.len()];
    bench(&mut report, "LZ4 decompress_into", "B", || {
        codec::decompress_into(CodecKind::Lz4, &enc, &mut lz4_out).unwrap();
        std::hint::black_box(&lz4_out);
        mixed.len()
    });
    bench(&mut report, "ZSTD compress (sparse)", "B", || {
        std::hint::black_box(codec::compress(CodecKind::Zstd, &mixed));
        mixed.len()
    });

    // compress_best: when a candidate codec wins, the raw input must NOT be
    // copied (the bypass-only materialization fix) — so best-of selection
    // over {RLE, LZ4} should run close to the sum of the codec costs, with
    // no extra 64 KB memcpy in the loop.
    let (win_kind, _) = compress_best(CodecPolicy::FastBest, &mixed);
    assert_ne!(win_kind, CodecKind::Raw, "sparse buffer must be compressible");
    let r = bench(&mut report, "compress_best (winner path)", "B", || {
        std::hint::black_box(compress_best(CodecPolicy::FastBest, &mixed));
        mixed.len()
    });
    gate(r > 80e6, &format!("compress_best winner-path gate 80 MB/s, got {:.0} MB/s", r / 1e6));

    // §Vector kernel gates (PR-7): each vectorized inner loop vs its scalar
    // predecessor on the same buffer, single thread. The scalar functions
    // are kept in-tree as `*_scalar` references precisely so these ratios
    // stay measurable (and the differential property tests stay honest).
    {
        let mut out = vec![0u8; 65536];

        // RLE: medium runs (16 B) — the near-constant shape of Mechanism
        // I's high-order delta planes. Short-to-medium runs are the
        // worst case for the scalar decoder (one memset call per run) and
        // exactly where the SWAR scan + wild u64 run fill pays off.
        let mut runs = vec![0u8; 65536];
        for (i, b) in runs.iter_mut().enumerate() {
            *b = ((i / 16) * 7 + 1) as u8;
        }
        let enc = codec::rle::compress(&runs);
        let v = bench(&mut report, "RLE decompress (vector)", "B", || {
            codec::rle::decompress_into(&enc, &mut out).unwrap();
            std::hint::black_box(&out);
            runs.len()
        });
        let s = bench(&mut report, "RLE decompress (scalar ref)", "B", || {
            codec::rle::decompress_into_scalar(&enc, &mut out).unwrap();
            std::hint::black_box(&out);
            runs.len()
        });
        report.record_raw("rle_decompress_speedup", v / s);
        gate(
            v >= 3.0 * s,
            &format!("RLE vector decompress gate 3x scalar, got {:.2}x", v / s),
        );

        // LZ4: 8-byte wild copies + offset-pattern splats vs exact-width
        // copies. Informational section (the hard kernel gates are RLE and
        // Huffman); the floor is only "no regression".
        let enc = codec::lz4::compress(&mixed);
        let v = bench(&mut report, "LZ4 decompress (vector)", "B", || {
            codec::lz4::decompress_into(&enc, &mut out).unwrap();
            std::hint::black_box(&out);
            mixed.len()
        });
        let s = bench(&mut report, "LZ4 decompress (scalar ref)", "B", || {
            codec::lz4::decompress_into_scalar(&enc, &mut out).unwrap();
            std::hint::black_box(&out);
            mixed.len()
        });
        report.record_raw("lz4_decompress_speedup", v / s);
        gate(
            v >= s,
            &format!("LZ4 vector decompress must not regress scalar, got {:.2}x", v / s),
        );

        // Huffman: 64-bit bit-buffer + 11-bit first-level table vs the
        // vendored bit-at-a-time reference, on low-entropy text-like bytes
        // (the shape that routes to MODE_HUFF in the first place).
        let mut text = vec![0u8; 65536];
        let mut tr = Rng::new(0x7EC5);
        for b in text.iter_mut() {
            *b = b'a' + (tr.below(13) as u8);
        }
        let enc = zstd::bulk::compress(&text, 3).unwrap();
        let v = bench(&mut report, "Huffman decompress (table)", "B", || {
            zstd::bulk::decompress_to_buffer(&enc, &mut out).unwrap();
            std::hint::black_box(&out);
            text.len()
        });
        let s = bench(&mut report, "Huffman decompress (bit ref)", "B", || {
            zstd::bulk::decompress_to_buffer_scalar(&enc, &mut out).unwrap();
            std::hint::black_box(&out);
            text.len()
        });
        report.record_raw("huffman_decompress_speedup", v / s);
        gate(
            v >= 2.0 * s,
            &format!("Huffman table decoder gate 2x bit reference, got {:.2}x", v / s),
        );

        // all-zero plane fast path: the dominant plane shape after
        // Mechanism I (high-order planes of smooth KV are entirely zero);
        // compress_best must answer from the one-entry memo, not by
        // running every candidate codec.
        let zeros = vec![0u8; 65536];
        let r = bench(&mut report, "compress_best (all-zero)", "B", || {
            std::hint::black_box(compress_best(CodecPolicy::FastBest, &zeros));
            zeros.len()
        });
        gate(r > 1e9, &format!("all-zero fast path gate 1 GB/s, got {:.2} GB/s", r / 1e9));
    }

    // device write/read path (Mechanism I end-to-end)
    let kv_blk = KvGen::default_for(64).generate(&mut rng, 64);
    let blk_bytes = kv_blk.len() * 2;
    bench(&mut report, "TRACE KV write path", "B", || {
        std::hint::black_box(DeviceBlock::encode_kv(
            &kv_blk,
            KvWindow::new(64, 64),
            CodecPolicy::FastBest,
        ));
        blk_bytes
    });
    let blk = DeviceBlock::encode_kv(&kv_blk, KvWindow::new(64, 64), CodecPolicy::FastBest);
    bench(&mut report, "TRACE KV read path", "B", || {
        std::hint::black_box(blk.decode_full().unwrap());
        blk_bytes
    });

    // §Zero-alloc gate: the scratch decode path. After warmup, a
    // steady-state single-block decode must touch the heap exactly zero
    // times — the counting global allocator makes this exact. The
    // scratch's own growth counter must agree.
    {
        let mut scratch = BlockScratch::new();
        let mut out = Vec::new();
        for _ in 0..4 {
            blk.decode_full_into(&mut scratch, &mut out).unwrap();
        }
        let grows_warm = scratch.growth_count();
        let before = allocations();
        let reps = 512usize;
        let t0 = Instant::now();
        for _ in 0..reps {
            blk.decode_full_into(&mut scratch, &mut out).unwrap();
            std::hint::black_box(&out);
        }
        let dt = t0.elapsed().as_secs_f64();
        let delta = allocations() - before;
        println!(
            "scratch decode (zero-alloc)  {:>10.1} MB/s   ({reps} iters, {delta} allocations)",
            blk_bytes as f64 * reps as f64 / dt / 1e6
        );
        assert_eq!(delta, 0, "steady-state single-block decode must not allocate");
        assert_eq!(
            scratch.growth_count(),
            grows_warm,
            "scratch buffers must not grow in steady state"
        );
        let rate = blk_bytes as f64 * reps as f64 / dt;
        report.record("scratch decode (zero-alloc)", rate, blk_bytes);
        report.record_raw("scratch_decode_allocations", delta as f64);
    }

    // §Codec-lane gate (PR-7): the 16 planes of ONE block decode
    // concurrently across the persistent lane pool. ZstdOnly makes every
    // plane a Huffman stream, so per-plane work dwarfs the lane handoff.
    // Lanes are wall-clock only — tests/hotpath_equiv.rs pins lanes-on
    // results bit-identical to serial — so this gate is the entire payoff.
    {
        let zblk = DeviceBlock::encode_kv(&kv_blk, KvWindow::new(64, 64), CodecPolicy::ZstdOnly);
        let lane1 = LanePool::new(1);
        let lane4 = LanePool::new(4);
        let mut scratch = BlockScratch::new();
        let mut out = Vec::new();
        let reps = if quick { 200 } else { 2000 };
        let time_with = |lanes: &LanePool, scratch: &mut BlockScratch, out: &mut Vec<u16>| {
            for _ in 0..4 {
                zblk.decode_full_into_lanes(scratch, out, lanes).unwrap();
            }
            let t0 = Instant::now();
            for _ in 0..reps {
                zblk.decode_full_into_lanes(scratch, out, lanes).unwrap();
                std::hint::black_box(&*out);
            }
            t0.elapsed().as_secs_f64() / reps as f64
        };
        let t1 = time_with(&lane1, &mut scratch, &mut out);
        let t4 = time_with(&lane4, &mut scratch, &mut out);
        let speedup = t1 / t4;
        println!(
            "single-block 16-plane decode  1 lane {:>8.2} us   4 lanes {:>8.2} us   speedup {speedup:.2}x",
            t1 * 1e6,
            t4 * 1e6
        );
        report.record_raw("lane_decode_1lane_us", t1 * 1e6);
        report.record_raw("lane_decode_4lane_us", t4 * 1e6);
        report.record_raw("lane_decode_speedup", speedup);
        gate(
            speedup >= 2.0,
            &format!("4 codec lanes must halve single-block decode wall time, got {speedup:.2}x"),
        );

        // Lanes keep the zero-alloc invariant: warm scratch + warm out +
        // the persistent lane pool touch the heap exactly zero times (the
        // counting allocator is global, so worker-thread allocations — if
        // any existed — would be caught too). Deterministic: asserts in
        // quick mode as well.
        let before = allocations();
        for _ in 0..256 {
            zblk.decode_full_into_lanes(&mut scratch, &mut out, &lane4).unwrap();
            std::hint::black_box(&out);
        }
        let delta = allocations() - before;
        assert_eq!(delta, 0, "lanes-on steady-state decode must not allocate");
        report.record_raw("lane_decode_allocations", delta as f64);
    }

    // DRAM simulator command rate
    let cfg = DramConfig::paper_default();
    let map = AddrMap::new(cfg);
    let reqs: Vec<Request> = map
        .bursts(0, 1 << 20)
        .into_iter()
        .map(|loc| Request { loc, is_write: false, arrival_ns: 0.0 })
        .collect();
    let n = reqs.len();
    let r = bench(&mut report, "DRAM sim (FR-FCFS)", "cmd", || {
        let mut sim = DramSim::new(cfg, EnergyParams::ddr5_4800());
        std::hint::black_box(sim.run_frfcfs(reqs.clone(), 16));
        n
    });
    gate(r > 5e6, &format!("DRAM sim target 5M cmd/s, got {:.1}M", r / 1e6));

    // Engine decode-step cost vs context length, all-HBM. The gather path
    // must NOT copy HBM-resident KV per step (the old `s.kv.clone()` made
    // every step O(context)); with the persistent work-buffer scatter the
    // per-step cost is O(pages-metadata + entry), so a ~30x longer context
    // must not cost anywhere near ~30x per step.
    {
        let dims = ModelDims {
            layers: 2,
            batch: 1,
            t_max: 4096,
            t_prompt: 8,
            d_model: 64,
            heads: 4,
            head_dim: 16,
            ffn: 128,
            vocab: 256,
        };
        let mut e = Engine::new(
            MockBackend::new(dims, 7),
            EngineConfig { hbm_kv_bytes: 1 << 30, ..Default::default() },
        );
        e.submit(vec![1, 2, 3, 4], 4000);
        let steps = |e: &mut Engine<MockBackend>, n: usize| -> f64 {
            let t0 = Instant::now();
            for _ in 0..n {
                e.step().unwrap();
            }
            t0.elapsed().as_secs_f64()
        };
        steps(&mut e, 16); // warm-up, ctx ~24
        let early = steps(&mut e, 100); // ctx ~25..125
        steps(&mut e, 3500); // advance to ctx ~3600
        let late = steps(&mut e, 100); // ctx ~3625..3725
        println!(
            "engine step, all-HBM KV       early(ctx~100) {:>8.1} us   late(ctx~3700) {:>8.1} us   ratio {:.2}x",
            early * 1e4, // 100 steps -> us/step
            late * 1e4,
            late / early
        );
        gate(
            late < 8.0 * early,
            &format!(
                "gather must not copy HBM-resident KV per step: early {early:.6}s late {late:.6}s"
            ),
        );
        assert_eq!(e.metrics.pages_spilled, 0, "all-HBM run must not spill");
        report.record_raw("engine_step_scaling_ratio", late / early);
    }

    // §Batch spill-decode gate: the PR-5 data path (4-way pool + decoded
    // plane cache + scratch) vs the PR-4 baseline (serial, no cache) on
    // the batched 4-shard spill-decode workload. Completions are
    // bit-identical either way (tests/hotpath_equiv.rs); this gate is the
    // wall-clock payoff.
    {
        let batches = if quick { 6 } else { 30 };
        let base = spill_decode_workload(1, 0, batches);
        let fast = spill_decode_workload(4, 1024, batches);
        let speedup = base / fast;
        println!(
            "batch 4-shard spill decode    base {:>8.1} us/batch   pool+cache {:>8.1} us/batch   speedup {speedup:.2}x",
            base * 1e6,
            fast * 1e6
        );
        report.record_raw("batch_decode_base_us", base * 1e6);
        report.record_raw("batch_decode_fast_us", fast * 1e6);
        report.record_raw("batch_decode_speedup", speedup);
        // relative, but still wall-clock: a shared CI runner can stall
        // either side, so quick mode reports instead of asserting
        gate(
            speedup >= 2.0,
            &format!(
                "pool+cache+scratch must beat the serial cache-off path >=2x, got {speedup:.2}x"
            ),
        );
    }

    // Full device round trip through the transaction API. NOTE: unlike the
    // pre-transaction bench, the measured loop now includes building the
    // owned WriteKv payload (an 8 KB clone) — the submission-queue contract
    // is owned buffers — so this number is not directly comparable to the
    // seed's `CxlDevice KV write+read` figure; the clone is small next to
    // the transform+codec work.
    let mut dev = CxlDevice::new(Design::Trace, CodecPolicy::FastBest);
    let mut addr = 0u64;
    bench(&mut report, "CxlDevice KV write+read (txn)", "B", || {
        dev.submit_one(Transaction::WriteKv {
            block_addr: addr,
            words: kv_blk.clone(),
            window: KvWindow::new(64, 64),
        })
        .unwrap();
        std::hint::black_box(
            dev.submit_one(Transaction::ReadFull { block_addr: addr }).unwrap(),
        );
        addr += 0x10000;
        blk_bytes * 2
    });

    report.write("BENCH_hotpaths.json");
}
