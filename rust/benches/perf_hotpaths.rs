//! §Perf — wall-clock microbenchmarks of the L3 hot paths (the
//! criterion-style harness; criterion itself is not in the offline vendor
//! set, so this uses a measured-loop harness with warmup).
//!
//! Targets (DESIGN.md §6): bit-transpose ≥ 1 GB/s/core, LZ4 compress ≥
//! 300 MB/s/core, KV transform ≥ 500 MB/s, DRAM sim ≥ 10 M cmds/s,
//! device write path ≥ 100 MB/s with ZSTD enabled.

use std::time::Instant;
use trace_cxl::bitplane::{transpose_from_planes, transpose_to_planes, DeviceBlock, KvTransform, KvWindow};
use trace_cxl::codec::{self, compress_best, CodecKind, CodecPolicy};
use trace_cxl::coordinator::{Engine, EngineConfig};
use trace_cxl::cxl::{CxlDevice, Design, MemDevice, Transaction};
use trace_cxl::dram::{AddrMap, DramConfig, DramSim, EnergyParams, Request};
use trace_cxl::gen::KvGen;
use trace_cxl::runtime::{MockBackend, ModelDims};
use trace_cxl::util::Rng;

fn bench<F: FnMut() -> usize>(name: &str, bytes_label: &str, mut f: F) -> f64 {
    // warmup
    let mut processed = 0usize;
    for _ in 0..2 {
        processed = f();
    }
    let t0 = Instant::now();
    let mut total = 0usize;
    let mut iters = 0;
    while t0.elapsed().as_secs_f64() < 0.5 {
        total += f();
        iters += 1;
    }
    let dt = t0.elapsed().as_secs_f64();
    let rate = total as f64 / dt;
    println!(
        "{name:<28} {:>10.1} M{bytes_label}/s   ({iters} iters, {processed} per iter)",
        rate / 1e6
    );
    rate
}

fn main() {
    let mut rng = Rng::new(0x9E7F);
    println!("# Perf hot paths (single core)");

    // bit transpose
    let words: Vec<u16> = (0..32 * 2048).map(|_| rng.next_u32() as u16).collect();
    let n_bytes = words.len() * 2;
    // Target revised after the §Perf pass (EXPERIMENTS.md): scalar SWAR
    // roofline on this box is ~0.7 GB/s; 0.5 GB/s is the regression gate.
    let r = bench("bit transpose (to planes)", "B", || {
        std::hint::black_box(transpose_to_planes(&words, 16));
        n_bytes
    });
    assert!(r > 250e6, "transpose gate 250 MB/s, got {:.0} MB/s", r / 1e6);

    let planes = transpose_to_planes(&words, 16);
    let r = bench("bit transpose (from planes)", "B", || {
        std::hint::black_box(transpose_from_planes(&planes, words.len(), 16, 0xffff));
        n_bytes
    });
    assert!(r > 150e6, "inverse transpose gate 150 MB/s, got {:.0} MB/s", r / 1e6);

    // KV transform
    let kv = KvGen::default_for(128).generate(&mut rng, 512);
    let kvb = kv.len() * 2;
    bench("KV transform (fwd)", "B", || {
        std::hint::black_box(KvTransform::forward(&kv, KvWindow::new(512, 128)));
        kvb
    });

    // codecs on a 64 KB plane-like buffer
    let mut mixed = vec![0u8; 65536];
    for (i, b) in mixed.iter_mut().enumerate() {
        *b = if i % 7 == 0 { (i / 97) as u8 } else { 0 };
    }
    let r = bench("LZ4 compress (sparse)", "B", || {
        std::hint::black_box(codec::compress(CodecKind::Lz4, &mixed));
        mixed.len()
    });
    assert!(r > 150e6, "LZ4 target 150 MB/s, got {:.0} MB/s", r / 1e6);
    let enc = codec::compress(CodecKind::Lz4, &mixed);
    bench("LZ4 decompress", "B", || {
        std::hint::black_box(codec::decompress(CodecKind::Lz4, &enc, mixed.len()).unwrap());
        mixed.len()
    });
    bench("ZSTD compress (sparse)", "B", || {
        std::hint::black_box(codec::compress(CodecKind::Zstd, &mixed));
        mixed.len()
    });

    // compress_best: when a candidate codec wins, the raw input must NOT be
    // copied (the bypass-only materialization fix) — so best-of selection
    // over {RLE, LZ4} should run close to the sum of the codec costs, with
    // no extra 64 KB memcpy in the loop.
    let (win_kind, _) = compress_best(CodecPolicy::FastBest, &mixed);
    assert_ne!(win_kind, CodecKind::Raw, "sparse buffer must be compressible");
    let r = bench("compress_best (winner path)", "B", || {
        std::hint::black_box(compress_best(CodecPolicy::FastBest, &mixed));
        mixed.len()
    });
    assert!(r > 80e6, "compress_best winner-path gate 80 MB/s, got {:.0} MB/s", r / 1e6);

    // device write/read path (Mechanism I end-to-end)
    let kv_blk = KvGen::default_for(64).generate(&mut rng, 64);
    let blk_bytes = kv_blk.len() * 2;
    bench("TRACE KV write path", "B", || {
        std::hint::black_box(DeviceBlock::encode_kv(
            &kv_blk,
            KvWindow::new(64, 64),
            CodecPolicy::FastBest,
        ));
        blk_bytes
    });
    let blk = DeviceBlock::encode_kv(&kv_blk, KvWindow::new(64, 64), CodecPolicy::FastBest);
    bench("TRACE KV read path", "B", || {
        std::hint::black_box(blk.decode_full().unwrap());
        blk_bytes
    });

    // DRAM simulator command rate
    let cfg = DramConfig::paper_default();
    let map = AddrMap::new(cfg);
    let reqs: Vec<Request> = map
        .bursts(0, 1 << 20)
        .into_iter()
        .map(|loc| Request { loc, is_write: false, arrival_ns: 0.0 })
        .collect();
    let n = reqs.len();
    let r = bench("DRAM sim (FR-FCFS)", "cmd", || {
        let mut sim = DramSim::new(cfg, EnergyParams::ddr5_4800());
        std::hint::black_box(sim.run_frfcfs(reqs.clone(), 16));
        n
    });
    assert!(r > 5e6, "DRAM sim target 5M cmd/s, got {:.1}M", r / 1e6);

    // Engine decode-step cost vs context length, all-HBM. The gather path
    // must NOT copy HBM-resident KV per step (the old `s.kv.clone()` made
    // every step O(context)); with the persistent work-buffer scatter the
    // per-step cost is O(pages-metadata + entry), so a ~30x longer context
    // must not cost anywhere near ~30x per step.
    {
        let dims = ModelDims {
            layers: 2,
            batch: 1,
            t_max: 4096,
            t_prompt: 8,
            d_model: 64,
            heads: 4,
            head_dim: 16,
            ffn: 128,
            vocab: 256,
        };
        let mut e = Engine::new(
            MockBackend::new(dims, 7),
            EngineConfig { hbm_kv_bytes: 1 << 30, ..Default::default() },
        );
        e.submit(vec![1, 2, 3, 4], 4000);
        let steps = |e: &mut Engine<MockBackend>, n: usize| -> f64 {
            let t0 = Instant::now();
            for _ in 0..n {
                e.step().unwrap();
            }
            t0.elapsed().as_secs_f64()
        };
        steps(&mut e, 16); // warm-up, ctx ~24
        let early = steps(&mut e, 100); // ctx ~25..125
        steps(&mut e, 3500); // advance to ctx ~3600
        let late = steps(&mut e, 100); // ctx ~3625..3725
        println!(
            "engine step, all-HBM KV       early(ctx~100) {:>8.1} us   late(ctx~3700) {:>8.1} us   ratio {:.2}x",
            early * 1e4, // 100 steps -> us/step
            late * 1e4,
            late / early
        );
        assert!(
            late < 8.0 * early,
            "gather must not copy HBM-resident KV per step: early {early:.6}s late {late:.6}s"
        );
        assert_eq!(e.metrics.pages_spilled, 0, "all-HBM run must not spill");
    }

    // Full device round trip through the transaction API. NOTE: unlike the
    // pre-transaction bench, the measured loop now includes building the
    // owned WriteKv payload (an 8 KB clone) — the submission-queue contract
    // is owned buffers — so this number is not directly comparable to the
    // seed's `CxlDevice KV write+read` figure; the clone is small next to
    // the transform+codec work.
    let mut dev = CxlDevice::new(Design::Trace, CodecPolicy::FastBest);
    let mut addr = 0u64;
    bench("CxlDevice KV write+read (txn)", "B", || {
        dev.submit_one(Transaction::WriteKv {
            block_addr: addr,
            words: kv_blk.clone(),
            window: KvWindow::new(64, 64),
        })
        .unwrap();
        std::hint::black_box(
            dev.submit_one(Transaction::ReadFull { block_addr: addr }).unwrap(),
        );
        addr += 0x10000;
        blk_bytes * 2
    });
}
