//! Table V — hardware cost (ASAP7 7 nm @ 2 GHz, 0.7 V): area, power,
//! load-to-use, and the component breakdown, from the calibrated PPA
//! inventory model (`cxl::ppa`, see DESIGN.md §Substitutions).

use trace_cxl::cxl::{ppa_for, Design};

fn main() {
    println!("# Table V: hardware cost (ASAP7 7nm @ 2GHz, 0.7V)");
    let reports: Vec<_> = [Design::Plain, Design::GComp, Design::Trace]
        .iter()
        .map(|&d| ppa_for(d))
        .collect();
    println!("{:<20} {:>12} {:>12} {:>12}", "", "CXL-Plain", "CXL-GComp", "TRACE");
    println!(
        "{:<20} {:>12.2} {:>12.2} {:>12.2}",
        "Area (mm2)",
        reports[0].area_mm2(),
        reports[1].area_mm2(),
        reports[2].area_mm2()
    );
    println!(
        "{:<20} {:>12.1} {:>12.1} {:>12.1}",
        "Power (W)",
        reports[0].power_w(),
        reports[1].power_w(),
        reports[2].power_w()
    );
    println!(
        "{:<20} {:>12} {:>12} {:>12}",
        "Load-to-use (cyc)",
        reports[0].load_to_use_cycles,
        reports[1].load_to_use_cycles,
        reports[2].load_to_use_cycles
    );
    println!("\nArea breakdown (mm2):");
    for comp in ["PHY", "Codec", "Codec SRAM", "Metadata", "Scheduler", "Transpose/Recon.", "Other"] {
        let cell = |r: &trace_cxl::cxl::PpaReport| {
            r.component(comp).map(|c| format!("{:.2}", c.area_mm2)).unwrap_or_else(|| "-".into())
        };
        println!(
            "{:<20} {:>12} {:>12} {:>12}",
            comp,
            cell(&reports[0]),
            cell(&reports[1]),
            cell(&reports[2])
        );
    }
    let delta_area =
        (reports[2].area_mm2() - reports[1].area_mm2()) / reports[1].area_mm2() * 100.0;
    let delta_pow = (reports[2].power_w() - reports[1].power_w()) / reports[1].power_w() * 100.0;
    let delta_lat = (reports[2].load_to_use_cycles as f64 - reports[1].load_to_use_cycles as f64)
        / reports[1].load_to_use_cycles as f64
        * 100.0;
    println!(
        "\nTRACE vs CXL-GComp: +{delta_area:.1}% area, +{delta_pow:.1}% power, +{delta_lat:.1}% load-to-use"
    );
    assert!((delta_area - 7.2).abs() < 0.5);
    assert!((delta_pow - 4.7).abs() < 0.7);
    assert!((delta_lat - 6.0).abs() < 0.5);
    println!("paper: +7.2% area, +4.7% power, +6.0% load-to-use");
}
