//! Fig. 17 — runtime precision distributions of MoDE-controlled weights
//! across BF16/FP8/INT4 bases for four models (the tier fractions the
//! Figs 18–19 experiments fetch at).

use trace_cxl::gen::precision::mode_mix;

fn main() {
    let models = ["LLaMA 3.1 8B", "LLaMA 3.1 70B", "Mixtral 8x7B", "LLaMA-MoE 3.5B"];
    // per-model average-bits budgets per base (importance-calibrated)
    let budgets = [
        (11.5f64, 6.4f64), // model 0: bf16-base avg, fp8-base avg
        (10.8, 6.1),
        (11.0, 6.2),
        (10.2, 5.9),
    ];

    println!("# Fig 17: MoDE runtime precision mixes (fraction of experts per tier)");
    println!(
        "{:<16} {:<6} {:>8} {:>8} {:>8} {:>10}",
        "Model", "Base", "16-bit", "8-bit", "4-bit", "avg bits"
    );
    for (mi, model) in models.iter().enumerate() {
        for (base, avg) in [(16usize, budgets[mi].0), (8, budgets[mi].1), (4, 4.0)] {
            let mix = mode_mix(base, avg);
            let frac_of = |bits: usize| -> f64 {
                mix.bits
                    .iter()
                    .zip(&mix.frac)
                    .find(|(&b, _)| b == bits)
                    .map(|(_, &f)| f)
                    .unwrap_or(0.0)
            };
            println!(
                "{:<16} {:<6} {:>8.2} {:>8.2} {:>8.2} {:>10.2}",
                model,
                format!("{}b", base),
                frac_of(16),
                frac_of(8),
                frac_of(4),
                mix.avg_bits()
            );
            assert!((mix.frac.iter().sum::<f64>() - 1.0).abs() < 1e-9);
        }
    }
    println!("\npaper: long-tailed mixes — most experts at reduced precision, few at full");
}
