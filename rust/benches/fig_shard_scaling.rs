//! §Sharding — aggregate read bandwidth vs shard count.
//!
//! Writes a fixed working set of KV blocks at stripe-interleaved addresses,
//! then drains one batched read submission against 1/2/4/8-shard devices
//! and reports the modeled aggregate read bandwidth (DRAM bytes served /
//! fleet wall-clock, where shards run their queues in parallel and the
//! slowest shard bounds the batch — see `cxl::sharded`).
//!
//! Gate (ISSUE 1 acceptance): 4 shards ≥ 2× the 1-shard aggregate read
//! bandwidth on the same workload. With balanced stripes the model gives
//! ~Nx, so the 2x gate has wide margin.
//!
//! Run: `cargo bench --bench fig_shard_scaling`

use trace_cxl::bitplane::KvWindow;
use trace_cxl::codec::CodecPolicy;
use trace_cxl::cxl::{
    Design, DispatchPolicy, MemDevice, ShardedDevice, SubmissionQueue, Transaction, STRIPE_BYTES,
};
use trace_cxl::util::check::smooth_kv;
use trace_cxl::util::Rng;

const BLOCKS: u64 = 64;
const TOKENS: usize = 32;
const CHANNELS: usize = 64;

/// (aggregate GB/s, serialized GB/s, bytes read) for one configuration.
fn read_bandwidth(shards: usize, policy: DispatchPolicy, kv: &[u16]) -> (f64, f64, u64) {
    let mut dev = ShardedDevice::with_policy(shards, Design::Trace, CodecPolicy::FastBest, policy);
    let mut sq = SubmissionQueue::new();
    for b in 0..BLOCKS {
        sq.submit(Transaction::WriteKv {
            block_addr: b * STRIPE_BYTES,
            words: kv.to_vec(),
            window: KvWindow::new(TOKENS, CHANNELS),
        });
    }
    for c in dev.drain(&mut sq) {
        c.result.expect("write");
    }
    dev.reset_stats();
    dev.reset_time();

    // one batched submission, as the coordinator's decode loop issues it
    let mut sq = SubmissionQueue::new();
    for b in 0..BLOCKS {
        sq.submit(Transaction::ReadFull { block_addr: b * STRIPE_BYTES });
    }
    let completions = dev.drain(&mut sq);
    assert_eq!(completions.len(), BLOCKS as usize);
    for c in &completions {
        assert!(c.result.is_ok());
    }
    let bytes = dev.stats().dram_bytes_read;
    // bytes/ns == GB/s
    (bytes as f64 / dev.elapsed_ns(), bytes as f64 / dev.total_busy_ns(), bytes)
}

fn main() {
    let mut rng = Rng::new(0x5AAD);
    let kv = smooth_kv(&mut rng, TOKENS, CHANNELS);

    println!("# fig_shard_scaling — aggregate device read bandwidth vs shards");
    println!("# {BLOCKS} blocks of {TOKENS}x{CHANNELS} BF16 KV, one batched ReadFull sweep\n");
    println!(
        "{:<8} {:>16} {:>16} {:>12} {:>10}",
        "shards", "aggregate GB/s", "serialized GB/s", "bytes", "speedup"
    );

    let mut base = 0.0f64;
    let mut four_speedup = 0.0f64;
    for shards in [1usize, 2, 4, 8] {
        let (agg, ser, bytes) = read_bandwidth(shards, DispatchPolicy::RoundRobin, &kv);
        if shards == 1 {
            base = agg;
        }
        let speedup = agg / base;
        if shards == 4 {
            four_speedup = speedup;
        }
        println!("{shards:<8} {agg:>16.2} {ser:>16.2} {bytes:>12} {speedup:>9.2}x");
    }

    // dispatch-policy comparison at 4 shards (same work, same bandwidth on
    // balanced placement; least-loaded only differs under skew)
    let (rr, _, _) = read_bandwidth(4, DispatchPolicy::RoundRobin, &kv);
    let (ll, _, _) = read_bandwidth(4, DispatchPolicy::LeastLoaded, &kv);
    println!("\n4-shard dispatch: round-robin {rr:.2} GB/s, least-loaded {ll:.2} GB/s");

    assert!(
        four_speedup >= 2.0,
        "4-shard aggregate read bandwidth must be >= 2x of 1 shard, got {four_speedup:.2}x"
    );
    assert!((rr - ll).abs() / rr < 0.05, "policies must agree on balanced placement");
    println!("\nOK: 4 shards sustain {four_speedup:.2}x the single-device aggregate read bandwidth");
}
