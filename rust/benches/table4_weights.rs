//! Table IV — lossless compression ratios on weights under TRACE, per
//! precision base (BF16 / FP8 / INT4), plus total savings vs BF16 when
//! combined with the lossy quantization step.

use trace_cxl::bitplane::{transpose_to_planes, plane_len};
use trace_cxl::codec::{compress_best, CodecPolicy};
use trace_cxl::formats::{fp8_e4m3_from_f32, int4_pack, int4_quantize};
use trace_cxl::gen::WeightGen;
use trace_cxl::util::Rng;

/// Compress a code stream (bits wide) through the TRACE per-plane path.
fn trace_ratio(words: &[u16], bits: usize) -> f64 {
    let flat = transpose_to_planes(words, bits);
    let pl = plane_len(words.len());
    let mut comp = 0usize;
    for p in 0..bits {
        let (_, c) = compress_best(CodecPolicy::ZstdOnly, &flat[p * pl..(p + 1) * pl]);
        comp += c.len();
    }
    (words.len() as f64 * bits as f64 / 8.0) / (comp as f64 + 2.0)
}

fn main() {
    let models = [
        ("LLaMA 3.1 8B", 4096usize),
        ("LLaMA 3.1 70B", 8192),
        ("Mixtral 8x7B", 4096),
        ("LLaMA MoE 3.5B", 2048),
    ];
    let mut rng = Rng::new(0xB4);
    let n = 16 * 2048; // 16 blocks worth of elements

    println!("# Table IV: TRACE lossless ratios on weights + total savings vs BF16");
    println!(
        "{:<16} {:>6} {:>12} {:>16} {:>20}",
        "Model", "Prec", "Comp.Ratio", "Lossless Sav %", "Total vs BF16 %"
    );
    for (name, d) in models {
        let gen = WeightGen::default_for(d.min(2048));
        let w32 = gen.generate_f32(&mut rng, n);
        let bf16: Vec<u16> = w32.iter().map(|&x| trace_cxl::formats::bf16_from_f32(x)).collect();
        let fp8: Vec<u16> = w32.iter().map(|&x| fp8_e4m3_from_f32(x) as u16).collect();
        let (codes4, _) = int4_quantize(&w32, 256);
        let int4: Vec<u16> = int4_pack(&codes4)
            .iter()
            .flat_map(|&b| [(b & 0xf) as u16, (b >> 4) as u16])
            .collect();

        for (prec, words, bits, lossy_factor) in [
            ("BF16", &bf16, 16usize, 1.0f64),
            ("FP8", &fp8, 8, 2.0),
            ("INT4", &int4, 4, 4.0),
        ] {
            let r = trace_ratio(words, bits);
            let lossless_sav = 100.0 * (1.0 - 1.0 / r);
            let total_sav = 100.0 * (1.0 - 1.0 / (r * lossy_factor));
            println!(
                "{:<16} {:>6} {:>12.2} {:>16.1} {:>20.1}",
                name, prec, r, lossless_sav, total_sav
            );
            // calibrated generators track the paper's ordering; synthetic
            // Gaussian weights have a slightly narrower exponent support
            // than trained checkpoints, so FP8 headroom runs a bit high.
            match prec {
                "BF16" => assert!(r > 1.15 && r < 1.6, "BF16 ratio {r}"),
                "FP8" => assert!(r > 1.0 && r < 1.55, "FP8 ratio {r}"),
                _ => assert!(r >= 0.99 && r < 1.3, "INT4 ratio {r}"),
            }
            assert!(
                prec != "INT4" || r < 1.3,
                "lossless headroom must shrink with base precision"
            );
        }
    }
    println!("\npaper: BF16 1.32-1.34x (24-26%), FP8 1.09-1.11x, INT4 1.01-1.02x; totals 54%/75% with quant");
}
