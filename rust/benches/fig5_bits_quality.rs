//! Figs 4/5 — per-unit precision control beats static-uniform at the same
//! average bit budget.
//!
//! Proxy for the paper's perplexity curves: reconstruction MSE of a
//! weight tensor whose rows have long-tailed importance, when bits are
//! assigned (a) uniformly vs (b) importance-aware per head/neuron, at the
//! same footprint-weighted average bits. Importance-aware must dominate
//! at every budget (the Fig. 5 gap).

use trace_cxl::formats::{bf16_truncate_view, bf16_from_f32, bf16_to_f32, mse};
use trace_cxl::gen::precision::zipf_importance;
use trace_cxl::util::Rng;

/// Serve a row at `bits` effective (sign+exp+mantissa truncation view).
fn serve_row(row: &[f32], bits: usize) -> Vec<f32> {
    let keep_man = bits.saturating_sub(9).min(7); // sign+8exp = 9 bits
    row.iter()
        .map(|&x| bf16_to_f32(bf16_truncate_view(bf16_from_f32(x), keep_man)))
        .collect()
}

fn main() {
    let mut rng = Rng::new(0xF5);
    let units = 64usize; // heads/neurons
    let row = 512usize;
    // unit importance: Zipf; important units have larger activations flowing
    // through them, so their weight error matters proportionally
    let imp = zipf_importance(units, 1.0);
    let weights: Vec<Vec<f32>> = (0..units)
        .map(|_| (0..row).map(|_| (rng.normal() * 0.05) as f32).collect())
        .collect();

    println!("# Fig 5: weighted reconstruction error vs average bits/weight");
    println!("{:<12} {:>16} {:>18} {:>10}", "avg bits", "uniform err", "per-unit err", "gain");
    for &budget in &[10.0f64, 11.0, 12.0, 13.0, 14.0] {
        // uniform: every unit at `budget` bits (fractional -> mix two levels)
        let lo = budget.floor() as usize;
        let frac_hi = budget - lo as f64;
        let uniform_err: f64 = weights
            .iter()
            .zip(&imp)
            .enumerate()
            .map(|(i, (w, &im))| {
                let bits = if (i as f64 / units as f64) < frac_hi { lo + 1 } else { lo };
                mse(w, &serve_row(w, bits)) * im
            })
            .sum();
        // importance-aware greedy water-filling: grant one mantissa bit at
        // a time to the unit with the largest marginal weighted-error
        // reduction (importance × error drop) — what per-head/per-neuron
        // alias selection lets the runtime do physically.
        let total_bits = (budget * units as f64).round() as usize;
        let mut bits_per = vec![9usize; units]; // floor: sign+exp
        let mut remaining = total_bits.saturating_sub(9 * units);
        while remaining > 0 {
            let mut best = usize::MAX;
            let mut best_gain = -1.0f64;
            for u in 0..units {
                if bits_per[u] >= 16 {
                    continue;
                }
                let k = (bits_per[u] - 9) as i32;
                let gain = imp[u] * (4f64.powi(-k) - 4f64.powi(-(k + 1)));
                if gain > best_gain {
                    best_gain = gain;
                    best = u;
                }
            }
            if best == usize::MAX {
                break;
            }
            bits_per[best] += 1;
            remaining -= 1;
        }
        let aware_err: f64 = weights
            .iter()
            .zip(&imp)
            .enumerate()
            .map(|(i, (w, &im))| mse(w, &serve_row(w, bits_per[i])) * im)
            .sum();
        let gain = uniform_err / aware_err.max(1e-18);
        println!("{budget:<12.1} {uniform_err:>16.3e} {aware_err:>18.3e} {gain:>9.2}x");
        assert!(
            aware_err <= uniform_err * 1.001,
            "importance-aware must not lose at budget {budget}"
        );
    }
    println!("\npaper Fig 5: per-head/per-neuron control dominates static-uniform at equal bits");
}
