//! Fig. 14 — throughput sensitivity to the HBM partition α under weight
//! spill (GPT-OSS-120B BF16): unimodal in α for every design; TRACE raises
//! the peak and shifts it toward larger α.

use trace_cxl::cxl::Design;
use trace_cxl::sysmodel::{ModelShape, SystemConfig, ThroughputModel};

fn main() {
    let mut shape = ModelShape::gpt_oss_120b_bf16();
    shape.kv_heads = 64;
    let m = ThroughputModel::new(SystemConfig::paper_default(), shape);
    let ctx = 65536;
    let alphas: Vec<f64> = (2..=19).map(|i| i as f64 * 0.05).collect();

    println!("# Fig 14: tok/s vs alpha (GPT-OSS-120B BF16, ctx=64k)");
    println!("{:<8} {:>10} {:>10} {:>10}", "alpha", "Plain", "GComp", "TRACE");
    let mut peaks = vec![(0.0f64, 0.0f64); 3];
    for &a in &alphas {
        let row: Vec<f64> = [Design::Plain, Design::GComp, Design::Trace]
            .iter()
            .map(|&d| {
                let mut cfg = SystemConfig::paper_default();
                cfg.alpha = a;
                ThroughputModel::new(cfg, m.shape.clone()).eval(ctx, d).tok_s
            })
            .collect();
        println!("{a:<8.3} {:>10.2} {:>10.2} {:>10.2}", row[0], row[1], row[2]);
        for (i, &t) in row.iter().enumerate() {
            if t > peaks[i].1 {
                peaks[i] = (a, t);
            }
        }
    }
    println!(
        "\npeaks: Plain {:.2} tok/s @ a={:.2}; GComp {:.2} @ a={:.2}; TRACE {:.2} @ a={:.2}",
        peaks[0].1, peaks[0].0, peaks[1].1, peaks[1].0, peaks[2].1, peaks[2].0
    );
    assert!(peaks[2].1 > peaks[1].1 && peaks[1].1 > peaks[0].1, "TRACE raises the peak");
    assert!(peaks[2].0 >= peaks[0].0, "TRACE peak alpha shifted right");
    println!("paper: Plain 30.89 @ 0.592, GComp 33.98 @ 0.592, TRACE 41.51 @ 0.771");
}
