//! Fig. 18 — DRAM access energy for weight reads under per-expert elastic
//! precision: CXL-Plain (word fetch, full containers) vs TRACE
//! (plane-aligned fetch) across BF16/FP8/INT4 bases on four models.
//!
//! Chunk sizes are scaled 1/8 from the paper's experts to bound bench
//! runtime; the Plain/TRACE ratio is scale-invariant (both streams scale
//! identically). Compression is disabled (paper: "to isolate plane-aligned
//! fetch").

use trace_cxl::dram::layout::{plane_fetch_requests, unit_scales, word_fetch_requests};
use trace_cxl::dram::{AddrMap, DramConfig, DramSim, EnergyParams};
use trace_cxl::gen::precision::mode_mix;
use trace_cxl::tier::{ChunkGranularity, WeightStore};
use trace_cxl::util::Rng;

fn main() {
    let cfg = DramConfig::paper_default();
    let map = AddrMap::new(cfg);
    let mut rng = Rng::new(0xF18);

    let models = [
        ("LLaMA 3.1 8B", 8usize, 11.5f64),
        ("LLaMA 3.1 70B", 8, 10.8),
        ("Mixtral 8x7B", 8, 11.0),
        ("LLaMA-MoE 3.5B", 8, 10.2),
    ];

    println!("# Fig 18: DRAM access energy, per-expert elastic precision (uJ per decode step)");
    println!(
        "{:<16} {:<6} {:>12} {:>12} {:>10}",
        "Model", "Base", "Plain (uJ)", "TRACE (uJ)", "saving %"
    );
    for (model, n_experts, bf16_avg) in models {
        for (base_bits, avg) in [(16usize, bf16_avg), (8, bf16_avg * 0.56), (4, 4.0)] {
            let mix = mode_mix(base_bits, avg);
            let mut store = WeightStore::new(
                &mut rng,
                0,
                ChunkGranularity::Expert,
                n_experts,
                &mix,
                base_bits,
            );
            store.region.elems /= 8; // runtime scaling (see header)
            // average over decode steps: routing re-draws 2 experts per step
            let steps = 12;
            let mut ep = 0.0;
            let mut et = 0.0;
            for _ in 0..steps {
                let fetches = store.routed(&mut rng, 2); // 2 routed experts/step
                let mut s1 = DramSim::new(cfg, EnergyParams::ddr5_4800());
                ep += s1
                    .run_frfcfs(word_fetch_requests(&map, store.region, &fetches, 0.0), 16)
                    .energy
                    .total_pj();
                let mut s2 = DramSim::new(cfg, EnergyParams::ddr5_4800());
                et += s2
                    .run_frfcfs(
                        plane_fetch_requests(
                            &map,
                            store.region,
                            n_experts,
                            &fetches,
                            &unit_scales(base_bits),
                            0.0,
                        ),
                        16,
                    )
                    .energy
                    .total_pj();
            }
            let (ep, et) = (ep / steps as f64 / 1e6, et / steps as f64 / 1e6);
            let saving = 100.0 * (1.0 - et / ep);
            println!(
                "{:<16} {:<6} {:>12.1} {:>12.1} {:>10.1}",
                model,
                format!("{base_bits}b"),
                ep,
                et,
                saving
            );
            if base_bits == 16 {
                // paper band: 25.9-29.9%; our mixes run slightly hotter on
                // the smallest model (avg 10.2 bits -> deeper savings)
                assert!(saving > 15.0 && saving < 55.0, "BF16 base saving {saving}");
            } else {
                assert!(saving >= -1.0, "plane fetch never loses");
            }
        }
    }
    println!("\npaper: up to 29.9% on BF16 bases; tapers on FP8 (19.6%) and INT4 (17.9%)");
}
