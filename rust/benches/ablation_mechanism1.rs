//! Ablation — where do Mechanism I's KV gains come from?
//!
//! The paper presents the transform as a chain (Fig. 8): cross-token
//! channel grouping → exponent-delta normalization → bit-plane packing →
//! codec. This bench ablates each stage on identical KV blocks (ZSTD,
//! 4 KB windows), isolating the contribution of every design choice
//! DESIGN.md calls out — including our zigzag delta encoding, without
//! which negative deltas (δ=−1 ⇒ 0xFF) destroy plane sparsity.

use trace_cxl::bitplane::{plane_len, transpose_to_planes, KvTransform, KvWindow};
use trace_cxl::codec::{compress, compress_best, CodecKind, CodecPolicy};
use trace_cxl::formats::{bf16_assemble, bf16_fields};
use trace_cxl::gen::KvGen;
use trace_cxl::util::bytes::u16s_to_bytes;
use trace_cxl::util::Rng;

fn plane_compressed(words: &[u16]) -> usize {
    let flat = transpose_to_planes(words, 16);
    let pl = plane_len(words.len());
    (0..16)
        .map(|r| compress_best(CodecPolicy::ZstdOnly, &flat[r * pl..(r + 1) * pl]).1.len())
        .sum()
}

/// Channel-major transpose only (no exponent transform).
fn channel_major(kv: &[u16], n: usize, c: usize) -> Vec<u16> {
    let mut out = vec![0u16; n * c];
    for t in 0..n {
        for j in 0..c {
            out[j * n + t] = kv[t * c + j];
        }
    }
    out
}

/// Exponent-delta with plain wraparound (NO zigzag): the naive encoding.
fn delta_no_zigzag(kv_cm: &[u16], n: usize, c: usize) -> Vec<u16> {
    let mut out = vec![0u16; n * c];
    for j in 0..c {
        // mode exponent
        let mut counts = [0u32; 256];
        for t in 0..n {
            let (_, e, _) = bf16_fields(kv_cm[j * n + t]);
            counts[e as usize] += 1;
        }
        let beta = (0..256).max_by_key(|&i| counts[i]).unwrap() as u8;
        for t in 0..n {
            let (s, e, m) = bf16_fields(kv_cm[j * n + t]);
            out[j * n + t] = bf16_assemble(s, (e as u8).wrapping_sub(beta) as u16, m);
        }
    }
    out
}

fn main() {
    let mut rng = Rng::new(0xAB1);
    let (n, c) = (64usize, 64usize);
    let blocks = 16;

    let mut raw_total = 0usize;
    let mut sizes = [0usize; 5]; // word-zstd, planes-only, +chan, +delta(no zz), full
    for _ in 0..blocks {
        let kv = KvGen::default_for(c).generate(&mut rng, n);
        raw_total += kv.len() * 2;
        // (0) word-major whole-block ZSTD (= CXL-GComp)
        sizes[0] += compress(CodecKind::Zstd, &u16s_to_bytes(&kv)).len();
        // (1) bit-planes only, token-major order
        sizes[1] += plane_compressed(&kv);
        // (2) + channel-major grouping
        let cm = channel_major(&kv, n, c);
        sizes[2] += plane_compressed(&cm);
        // (3) + exponent delta WITHOUT zigzag
        sizes[3] += plane_compressed(&delta_no_zigzag(&cm, n, c));
        // (4) full Mechanism I (delta with zigzag), via the real pipeline
        let t = KvTransform::forward(&kv, KvWindow::new(n, c));
        sizes[4] += plane_compressed(&t.words);
    }

    let names = [
        "word-major ZSTD (GComp)",
        "bit-planes only",
        "+ channel grouping",
        "+ exp-delta (no zigzag)",
        "+ exp-delta zigzag (TRACE)",
    ];
    println!("# Ablation: Mechanism I stage-by-stage (ZSTD, {blocks} x 4KB KV windows)");
    println!("{:<30} {:>12} {:>10}", "configuration", "bytes", "ratio");
    for (i, name) in names.iter().enumerate() {
        println!("{:<30} {:>12} {:>10.2}", name, sizes[i], raw_total as f64 / sizes[i] as f64);
    }
    // each stage must help (zigzag vs no-zigzag is the repo's own finding)
    assert!(sizes[2] < sizes[1], "channel grouping helps");
    assert!(sizes[4] < sizes[2], "exponent delta helps on top of grouping");
    assert!(sizes[4] < sizes[3], "zigzag encoding is required for plane sparsity");
    assert!(sizes[4] < sizes[0], "full chain beats word-major ZSTD");
    println!("\nevery stage contributes; zigzag delta is essential (naive wraparound sets all");
    println!("delta planes for negative deltas and gives back most of the gain)");
}
