//! Fig. 15 — per-layer KV lossless compression ratio (LLaMA-3.1-8B, 32
//! layers) on two corpora, 4 KB blocks, LZ4/ZSTD: TRACE (Mechanism I +
//! bit-planes) vs CXL-GComp (direct compression of the token-major
//! stream). TRACE must win on essentially every layer, with the overall
//! ratio in the paper's band and peak layers well above it.

use trace_cxl::bitplane::{DeviceBlock, KvWindow};
use trace_cxl::codec::{compress, CodecKind, CodecPolicy};
use trace_cxl::gen::KvGen;
use trace_cxl::util::bytes::u16s_to_bytes;
use trace_cxl::util::Rng;

fn main() {
    let layers = 32usize;
    let channels = 64usize; // one head-group stream per block
    let tokens = 64usize;
    let blocks_per_layer = 4usize;

    println!("# Fig 15: per-layer KV compression ratio (32 layers, 4KB blocks)");
    for (corpus, seed, smooth_boost) in [("WikiText", 0x15A_u64, 0.004), ("BookSum", 0x15B, 0.005)] {
        println!("\n== {corpus} ==");
        println!(
            "{:<7} {:>12} {:>12} {:>12} {:>12}",
            "layer", "TRACE LZ4", "TRACE ZSTD", "GComp LZ4", "GComp ZSTD"
        );
        let mut rng = Rng::new(seed);
        let mut tot = [0f64; 4];
        let mut peak = [0f64; 4];
        for layer in 0..layers {
            let mut g = KvGen::for_layer(channels, layer, layers);
            g.smooth = (g.smooth + smooth_boost * layer as f64 / layers as f64).min(0.995);
            let mut ratios = [0f64; 4];
            for _ in 0..blocks_per_layer {
                let kv = g.generate(&mut rng, tokens);
                let raw = u16s_to_bytes(&kv);
                let t_lz4 =
                    DeviceBlock::encode_kv(&kv, KvWindow::new(tokens, channels), CodecPolicy::Lz4Only);
                let t_zstd =
                    DeviceBlock::encode_kv(&kv, KvWindow::new(tokens, channels), CodecPolicy::ZstdOnly);
                ratios[0] += t_lz4.ratio();
                ratios[1] += t_zstd.ratio();
                ratios[2] += raw.len() as f64
                    / compress(CodecKind::Lz4, &raw).len().min(raw.len()) as f64;
                ratios[3] += raw.len() as f64
                    / compress(CodecKind::Zstd, &raw).len().min(raw.len()) as f64;
            }
            for r in ratios.iter_mut() {
                *r /= blocks_per_layer as f64;
            }
            println!(
                "{:<7} {:>12.2} {:>12.2} {:>12.2} {:>12.2}",
                layer, ratios[0], ratios[1], ratios[2], ratios[3]
            );
            for i in 0..4 {
                tot[i] += ratios[i] / layers as f64;
                peak[i] = peak[i].max(ratios[i]);
            }
            assert!(ratios[1] > ratios[3], "TRACE ZSTD must beat GComp ZSTD at layer {layer}");
        }
        println!(
            "overall: TRACE lz4 {:.2} zstd {:.2} | GComp lz4 {:.2} zstd {:.2}  (peak TRACE zstd {:.2})",
            tot[0], tot[1], tot[2], tot[3], peak[1]
        );
        assert!(tot[1] > 1.4, "TRACE overall in the paper band (1.81/1.88)");
        assert!(tot[3] < 1.45, "GComp stays weak (paper 1.21/1.33)");
        assert!(peak[1] > tot[1] * 1.08, "peaky per-layer distribution (paper peak 2.69)");
    }
    println!("\npaper: TRACE 1.81 (WikiText) / 1.88 (BookSum); GComp 1.21 / 1.33; peaks to 2.69x");
}
