//! Fig. 23 — TRACE load-to-use vs compression ratio: higher compression
//! fetches fewer planes (shorter burst, less exposed codec), 89 cycles at
//! 1.5x down to 85 at 3x; incompressible blocks take the bypass path at
//! 76 cycles.

use trace_cxl::cxl::{latency, LatencyCase};

fn main() {
    println!("# Fig 23: TRACE latency vs compression ratio (metadata-cache hit)");
    println!("{:<12} {:>8} {:>8} {:>8} {:>8}", "ratio", "burst", "codec", "total", "ns");
    let mut last = u32::MAX;
    for r in [1.5f64, 2.0, 2.5, 3.0] {
        let b = latency(LatencyCase::Trace { metadata_hit: true, ratio: r, bypass: false });
        println!(
            "{:<12} {:>8} {:>8} {:>8} {:>8.1}",
            format!("{r:.1}x"),
            b.burst,
            b.codec,
            b.total_cycles(),
            b.total_ns()
        );
        assert!(b.total_cycles() <= last, "monotone in ratio");
        last = b.total_cycles();
    }
    let bypass = latency(LatencyCase::Trace { metadata_hit: true, ratio: 1.0, bypass: true });
    println!(
        "{:<12} {:>8} {:>8} {:>8} {:>8.1}",
        "bypass", bypass.burst, bypass.codec, bypass.total_cycles(), bypass.total_ns()
    );
    assert_eq!(
        latency(LatencyCase::Trace { metadata_hit: true, ratio: 1.5, bypass: false }).total_cycles(),
        89
    );
    assert_eq!(
        latency(LatencyCase::Trace { metadata_hit: true, ratio: 3.0, bypass: false }).total_cycles(),
        85
    );
    assert_eq!(bypass.total_cycles(), 76);
    assert_eq!(bypass.codec, 0, "bypass skips the codec");
    println!("\npaper: 89 cycles @1.5x -> 85 @3x; incompressible bypass 76 cycles");
}
