//! Figs 20 & 21 — OPT-30B per-attention-head and per-MLP-neuron elastic
//! precision: total DRAM access energy for one full model load (Fig. 20)
//! and per-weight energy split into read vs activation (Fig. 21), at
//! average bits/weight targets 1.6 / 4.8 / 8.0, plus the B-16.0 full load.
//!
//! Head chunks use the paper's 3.7e6 weights (count scaled down), neuron
//! chunks the paper's 7.2e3 weights.

use trace_cxl::dram::layout::{plane_fetch_requests, unit_scales, word_fetch_requests, ChunkFetch, Region};
use trace_cxl::dram::{AddrMap, DramConfig, DramSim, EnergyParams};
use trace_cxl::util::Rng;

fn assign_bits(rng: &mut Rng, n: usize, avg: f64) -> Vec<usize> {
    // two-point ladder around the target on {1..16}
    let lo = avg.floor().max(1.0) as usize;
    let hi = (lo + 1).min(16);
    let f_hi = (avg - lo as f64).clamp(0.0, 1.0);
    (0..n).map(|_| if rng.chance(f_hi) { hi } else { lo }).collect()
}

fn run(region: Region, n_chunks: usize, bits: &[usize], plane: bool) -> trace_cxl::dram::SimStats {
    let cfg = DramConfig::paper_default();
    let map = AddrMap::new(cfg);
    let fetches: Vec<ChunkFetch> =
        (0..n_chunks).map(|c| ChunkFetch { chunk: c, bits: bits[c] }).collect();
    let reqs = if plane {
        plane_fetch_requests(&map, region, n_chunks, &fetches, &unit_scales(16), 0.0)
    } else {
        word_fetch_requests(&map, region, &fetches, 0.0)
    };
    let mut sim = DramSim::new(cfg, EnergyParams::ddr5_4800());
    sim.run_frfcfs(reqs, 16)
}

fn main() {
    let mut rng = Rng::new(0xF20);
    println!("# Fig 20/21: OPT-30B full-model-load DRAM energy, per-head / per-neuron");
    for (gran, elems, n_chunks) in [("per-head", 3_700_000usize / 16, 16usize), ("per-neuron", 7_200, 512)] {
        let region = Region { base: 0, elems, container_bits: 16 };
        println!("\n== {gran} (chunk={elems} elems x {n_chunks}) ==");
        println!(
            "{:<10} {:>12} {:>12} {:>9} | {:>12} {:>12} {:>12} {:>12}",
            "bits", "B total mJ", "T total mJ", "save %", "B rd pJ/w", "B act pJ/w", "T rd pJ/w", "T act pJ/w"
        );
        // B-16.0 baseline row
        let full_bits = vec![16usize; n_chunks];
        let b16 = run(region, n_chunks, &full_bits, false);
        let nw = (elems * n_chunks) as f64;
        println!(
            "{:<10} {:>12.2} {:>12} {:>9} | {:>12.1} {:>12.1} {:>12} {:>12}",
            "B-16.0",
            b16.energy.total_pj() / 1e9,
            "-",
            "-",
            (b16.energy.rd_pj + b16.energy.io_pj) / nw,
            b16.energy.act_pj / nw,
            "-",
            "-"
        );
        for &target in &[1.6f64, 4.8, 8.0] {
            let bits = assign_bits(&mut rng, n_chunks, target);
            let b = run(region, n_chunks, &bits, false);
            let t = run(region, n_chunks, &bits, true);
            let save = 100.0 * (1.0 - t.energy.total_pj() / b.energy.total_pj());
            println!(
                "{:<10} {:>12.2} {:>12.2} {:>9.1} | {:>12.1} {:>12.1} {:>12.1} {:>12.1}",
                format!("{target}"),
                b.energy.total_pj() / 1e9,
                t.energy.total_pj() / 1e9,
                save,
                (b.energy.rd_pj + b.energy.io_pj) / nw,
                b.energy.act_pj / nw,
                (t.energy.rd_pj + t.energy.io_pj) / nw,
                t.energy.act_pj / nw
            );
            assert!(save > 10.0 && save < 95.0, "{gran} @{target}: save {save}");
            // lower targets save more in absolute plane terms
        }
    }
    println!("\npaper: up to 40.3% total energy reduction; per-head 30.5/40.4/40.9% at 1.6/4.8/8.0 bits;");
    println!("per-neuron 19.4/20.3/33.9%; latency follows the same trend");
}
