//! Fig. 12 — decoding throughput vs context, GPT-OSS-120B-MXFP4 (~60 GB
//! weights fit in 76 GB HBM; only KV spills). All designs overlap until
//! KV spills; then CXL-GComp ≈ CXL-Plain (token-major KV incompressible)
//! while TRACE sustains far higher throughput.
//!
//! Calibration notes (EXPERIMENTS.md): KV traffic uses the full-head
//! (MHA) shape and the hot-set threshold model; `TRACE+tiers` adds the
//! elastic cold-KV alias (Mechanism II) that the paper's headline 4.24x
//! at 128k implies.

use trace_cxl::cxl::Design;
use trace_cxl::sysmodel::{ModelShape, SystemConfig, ThroughputModel};

fn main() {
    let mut shape = ModelShape::gpt_oss_120b_mxfp4();
    shape.kv_heads = 64;
    let m = ThroughputModel::new(SystemConfig::paper_default(), shape.clone());
    let me = ThroughputModel::new(SystemConfig::paper_default().with_elastic_kv(2.0), shape);

    println!("# Fig 12: tok/s vs context (GPT-OSS-120B-MXFP4, weights fit in HBM)");
    println!(
        "{:<10} {:>10} {:>10} {:>10} {:>14} {:>10}",
        "ctx", "Plain", "GComp", "TRACE", "TRACE+tiers", "kv spill%"
    );
    let ctxs = [4096usize, 16384, 65536, 131072, 196608, 262144];
    let mut plain128 = 0.0;
    let mut tiers128 = 0.0;
    let mut plateau = 0.0;
    for &ctx in &ctxs {
        let p = m.eval(ctx, Design::Plain);
        let g = m.eval(ctx, Design::GComp);
        let t = m.eval(ctx, Design::Trace);
        let te = me.eval(ctx, Design::Trace);
        println!(
            "{:<10} {:>10.2} {:>10.2} {:>10.2} {:>14.2} {:>10.1}",
            ctx,
            p.tok_s,
            g.tok_s,
            t.tok_s,
            te.tok_s,
            p.kv_spill_frac * 100.0
        );
        if ctx == 65536 {
            plateau = p.tok_s;
        }
        if ctx == 131072 {
            plain128 = p.tok_s;
            tiers128 = te.tok_s;
        }
    }
    let gain = tiers128 / plain128;
    println!("\nat 128k: TRACE+tiers {tiers128:.2} vs Plain {plain128:.2} tok/s = {gain:.2}x (paper: 68.99 vs 16.28 = 4.24x)");
    assert!(gain > 3.0, "TRACE must recover most of the plateau");
    assert!(tiers128 > 0.8 * plateau);
}
