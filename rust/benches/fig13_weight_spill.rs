//! Fig. 13 — GPT-OSS-120B in BF16 (~240 GB weights > 76 GB HBM, α = 0.8):
//! curves separate already at short context because weight reads hit CXL
//! (GComp > Plain since weights do compress word-major; TRACE higher
//! still), then all fall off the KV cliff at long context where TRACE
//! remains on top.

use trace_cxl::cxl::Design;
use trace_cxl::sysmodel::{ModelShape, SystemConfig, ThroughputModel};

fn main() {
    let mut shape = ModelShape::gpt_oss_120b_bf16();
    shape.kv_heads = 64;
    let m = ThroughputModel::new(SystemConfig::paper_default(), shape.clone());
    let me = ThroughputModel::new(SystemConfig::paper_default().with_elastic_kv(2.0), shape);

    println!("# Fig 13: tok/s vs context (GPT-OSS-120B BF16, weights spill, alpha=0.8)");
    println!(
        "{:<10} {:>10} {:>10} {:>10} {:>14} {:>10} {:>10}",
        "ctx", "Plain", "GComp", "TRACE", "TRACE+tiers", "w spill%", "kv spill%"
    );
    let ctxs = [4096usize, 16384, 65536, 131072, 196608, 262144];
    let mut short = (0.0, 0.0, 0.0);
    let mut long = (0.0, 0.0, 0.0, 0.0);
    for &ctx in &ctxs {
        let p = m.eval(ctx, Design::Plain);
        let g = m.eval(ctx, Design::GComp);
        let t = m.eval(ctx, Design::Trace);
        let te = me.eval(ctx, Design::Trace);
        println!(
            "{:<10} {:>10.2} {:>10.2} {:>10.2} {:>14.2} {:>10.1} {:>10.1}",
            ctx, p.tok_s, g.tok_s, t.tok_s, te.tok_s,
            p.w_spill_frac * 100.0,
            p.kv_spill_frac * 100.0
        );
        if ctx == 4096 {
            short = (p.tok_s, g.tok_s, t.tok_s);
        }
        if ctx == 131072 {
            long = (p.tok_s, g.tok_s, t.tok_s, te.tok_s);
        }
    }
    // paper shape: separation at 4k (33.61 < 36.97 < 42.02); TRACE ~3.6x at
    // 128k (with the elastic cold-KV tiers the headline number implies)
    assert!(short.1 > short.0 && short.2 > short.1, "weight-spill separation at 4k");
    assert!(long.2 > 1.4 * long.0, "lossless TRACE leads at 128k");
    assert!(long.3 > 2.0 * long.0, "TRACE+tiers leads at 128k (paper ~3.6x)");
    println!(
        "\nat 4k: {:.2} < {:.2} < {:.2} (paper 33.61/36.97/42.02); at 128k TRACE/Plain = {:.2}x lossless, {:.2}x with tiers (paper ~3.6x)",
        short.0, short.1, short.2, long.2 / long.0, long.3 / long.0
    );
}
