//! §Scenarios — the named workload library end-to-end, plus the
//! shared-prefix KV dedup gate.
//!
//! Runs every scenario in `gen::scenarios` through the serving engine
//! with a zero-HBM KV budget (every page lives on the CXL device, so
//! device footprint *is* KV footprint) and reports tokens, model time,
//! peak device footprint, and tier/preemption counters.
//!
//! Gates (ISSUE 6 acceptance):
//!
//! * every scenario finishes all its requests and drains the device;
//! * rag-fanout actually shares pages (`pages_shared > 0`);
//! * shared prefixes cut the peak KV device footprint by >=40% vs the
//!   identical workload with the prefix declarations stripped;
//! * sharing also writes strictly fewer device DRAM bytes (each shared
//!   page is written once, not once per sharer).
//!
//! Run: `cargo bench --bench fig_scenarios`

use trace_cxl::coordinator::{Engine, EngineConfig};
use trace_cxl::cxl::MemDevice;
use trace_cxl::gen::scenarios::{self, ScenarioRequest};
use trace_cxl::runtime::{MockBackend, ModelDims};

const SEED: u64 = 17;
const N_REQUESTS: usize = 16;
const MAX_NEW_CAP: usize = 8;

fn dims() -> ModelDims {
    ModelDims {
        layers: 2,
        batch: 4,
        t_max: 256,
        t_prompt: 112,
        d_model: 16,
        heads: 2,
        head_dim: 4,
        ffn: 32,
        vocab: 64,
    }
}

struct Run {
    tokens: u64,
    model_ns: f64,
    peak_footprint: usize,
    dram_wr: u64,
    pages_spilled: u64,
    pages_shared: u64,
    preemptions: u64,
}

/// Serve one request list to completion, tracking the peak device
/// footprint across steps (zero HBM budget: the device holds every page).
fn run(reqs: &[ScenarioRequest], label: &str) -> Run {
    let mut e = Engine::new(
        MockBackend::new(dims(), 42),
        EngineConfig { hbm_kv_bytes: 0, ..Default::default() },
    );
    for r in reqs {
        match r.prefix {
            Some(p) => e.submit_shared_at(r.prompt.clone(), r.max_new, r.arrival_ns, r.sla, p),
            None => e.submit_at(r.prompt.clone(), r.max_new, r.arrival_ns, r.sla),
        };
    }
    let mut peak = 0usize;
    let mut steps = 0usize;
    while e.pending() > 0 {
        e.step().unwrap();
        peak = peak.max(e.device.footprint_bytes());
        steps += 1;
        assert!(steps < 500_000, "{label}: runaway scenario");
    }
    assert_eq!(
        e.metrics.requests_finished as usize,
        reqs.len(),
        "{label}: every request must finish"
    );
    assert_eq!(e.device.len(), 0, "{label}: device must drain after retire");
    let d = e.device.stats();
    Run {
        tokens: e.metrics.tokens_generated,
        model_ns: e.metrics.model_ns,
        peak_footprint: peak,
        dram_wr: d.dram_bytes_written,
        pages_spilled: e.metrics.pages_spilled,
        pages_shared: e.metrics.pages_shared,
        preemptions: e.metrics.preemptions,
    }
}

fn main() {
    let d = dims();
    println!("# fig_scenarios — named workload library + shared-prefix KV dedup");
    println!(
        "# {N_REQUESTS} requests/scenario, seed {SEED}, t_prompt {}, max_new <= {MAX_NEW_CAP}, \
         HBM-KV 0 (all pages on device)\n",
        d.t_prompt
    );
    println!(
        "{:<16} {:>7} {:>12} {:>12} {:>9} {:>8} {:>8} {:>8}",
        "scenario", "tokens", "model us", "peak KV", "dram wr", "spilled", "shared", "preempt"
    );

    let mut rag: Option<(Vec<ScenarioRequest>, Run)> = None;
    for sc in scenarios::all() {
        let reqs = sc.generate(SEED, N_REQUESTS, d.vocab as u32, d.t_prompt, MAX_NEW_CAP);
        let r = run(&reqs, sc.name);
        println!(
            "{:<16} {:>7} {:>12.1} {:>12} {:>9} {:>8} {:>8} {:>8}",
            sc.name,
            r.tokens,
            r.model_ns / 1000.0,
            r.peak_footprint,
            r.dram_wr,
            r.pages_spilled,
            r.pages_shared,
            r.preemptions
        );
        if sc.name == "rag-fanout" {
            rag = Some((reqs, r));
        }
    }
    let (rag_reqs, shared) = rag.expect("catalogue contains rag-fanout");
    assert!(shared.pages_shared > 0, "rag-fanout must attach to shared pages");

    // control: the identical workload with the prefix declarations
    // stripped — every request commits its own copy of the document
    let unshared_reqs: Vec<ScenarioRequest> =
        rag_reqs.iter().map(|r| ScenarioRequest { prefix: None, ..r.clone() }).collect();
    let unshared = run(&unshared_reqs, "rag-fanout/unshared");
    assert_eq!(unshared.pages_shared, 0, "control must not share");
    assert_eq!(shared.tokens, unshared.tokens, "sharing must not change the served tokens");

    let ratio = shared.peak_footprint as f64 / unshared.peak_footprint as f64;
    println!(
        "\n# rag-fanout dedup: peak KV footprint {} shared vs {} unshared ({:.0}% saved), \
         dram wr {} vs {}",
        shared.peak_footprint,
        unshared.peak_footprint,
        100.0 * (1.0 - ratio),
        shared.dram_wr,
        unshared.dram_wr
    );
    assert!(
        ratio <= 0.60,
        "shared prefixes must cut peak KV device footprint >=40% (got {:.0}%)",
        100.0 * (1.0 - ratio)
    );
    assert!(
        shared.dram_wr < unshared.dram_wr,
        "each shared page must be written once, not once per sharer \
         ({} vs {})",
        shared.dram_wr,
        unshared.dram_wr
    );
    println!(
        "\nOK: 5 scenarios served end-to-end; rag-fanout dedup saves {:.0}% peak KV footprint",
        100.0 * (1.0 - ratio)
    );
}
