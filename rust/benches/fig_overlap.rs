//! §Overlap — serial vs overlapped decode pipeline, model-time tok/s
//! across context lengths.
//!
//! Runs the full engine (mock backend, TRACE device) twice per operating
//! point — serial and overlapped — and reports model-time throughput.
//! Gates (ISSUE 3 acceptance):
//!
//! * tokens and aggregate device byte traffic are bit-identical between
//!   the two pipelines at every point;
//! * the overlapped pipeline is **strictly** faster in model time
//!   whenever spilled-page traffic is nonzero, and exactly equal when
//!   nothing spills (there is nothing to hide);
//! * the analytic model (`sysmodel::OverlapMode`) agrees directionally.
//!
//! Run: `cargo bench --bench fig_overlap`

use trace_cxl::coordinator::{Engine, EngineConfig};
use trace_cxl::cxl::{Design, DeviceStats, MemDevice};
use trace_cxl::runtime::{MockBackend, ModelDims};
use trace_cxl::sysmodel::{ModelShape, OverlapMode, SystemConfig, ThroughputModel};

fn dims() -> ModelDims {
    ModelDims {
        layers: 2,
        batch: 2,
        t_max: 512,
        t_prompt: 8,
        d_model: 32,
        heads: 2,
        head_dim: 8,
        ffn: 64,
        vocab: 128,
    }
}

struct Run {
    tokens: Vec<Vec<u32>>,
    stats: DeviceStats,
    spilled: u64,
    model_ns: f64,
    generated: u64,
    prefetch_hits: u64,
}

fn run(max_new: usize, hbm: u64, overlap: bool) -> Run {
    let mut e = Engine::new(
        MockBackend::new(dims(), 42),
        EngineConfig { design: Design::Trace, hbm_kv_bytes: hbm, overlap, ..Default::default() },
    );
    e.submit(vec![1, 2, 3, 4, 5], max_new);
    e.submit(vec![6, 7, 8], max_new);
    e.run_to_completion(5_000).unwrap();
    let mut rs = e.take_responses();
    rs.sort_by_key(|r| r.id);
    Run {
        tokens: rs.into_iter().map(|r| r.tokens).collect(),
        stats: e.device.stats(),
        spilled: e.metrics.pages_spilled,
        model_ns: e.metrics.model_ns,
        generated: e.metrics.tokens_generated,
        prefetch_hits: e.metrics.prefetch_hits,
    }
}

fn main() {
    println!("# fig_overlap — serial vs overlapped pipeline, model-time tok/s");
    println!("# mock backend, TRACE device, compute_ns={}\n", EngineConfig::default().compute_ns);
    println!(
        "{:<16} {:>8} {:>14} {:>16} {:>10} {:>10}",
        "point", "spilled", "serial tok/s", "overlap tok/s", "speedup", "hits"
    );

    // (label, max_new per request, HBM-KV budget): the first point fits
    // entirely in HBM; the rest spill progressively more context
    let points: [(&str, usize, u64); 4] = [
        ("no-spill", 48, 1 << 20),
        ("ctx~32", 24, 2048),
        ("ctx~104", 96, 2048),
        ("ctx~200", 192, 2048),
    ];
    for (label, max_new, hbm) in points {
        let s = run(max_new, hbm, false);
        let o = run(max_new, hbm, true);
        assert_eq!(s.tokens, o.tokens, "{label}: tokens must be bit-identical");
        assert_eq!(s.stats, o.stats, "{label}: device byte traffic must be identical");
        assert_eq!(s.generated, o.generated);
        let s_tok = s.generated as f64 / (s.model_ns * 1e-9);
        let o_tok = o.generated as f64 / (o.model_ns * 1e-9);
        println!(
            "{:<16} {:>8} {:>14.1} {:>16.1} {:>9.3}x {:>10}",
            label,
            s.spilled,
            s_tok,
            o_tok,
            o_tok / s_tok,
            o.prefetch_hits
        );
        if s.spilled > 0 {
            assert!(
                o.model_ns < s.model_ns,
                "{label}: overlap must strictly beat serial once spill traffic is nonzero \
                 (serial {} ns, overlapped {} ns)",
                s.model_ns,
                o.model_ns
            );
        } else {
            assert!(
                (o.model_ns - s.model_ns).abs() < 1e-6,
                "{label}: with zero spill the pipelines must coincide"
            );
        }
    }

    // analytic cross-check: the closed-form model's overlap mode points
    // the same direction at the paper's Fig. 12 spill regime
    let mut shape = ModelShape::gpt_oss_120b_mxfp4();
    shape.kv_heads = 64;
    let serial = ThroughputModel::new(
        SystemConfig::paper_default().with_overlap(OverlapMode::Serial),
        shape.clone(),
    );
    let overlapped = ThroughputModel::new(
        SystemConfig::paper_default().with_overlap(OverlapMode::Overlapped),
        shape,
    );
    println!("\n# analytic (Fig. 12 shape, 128k): serial vs overlapped");
    for d in [Design::Plain, Design::GComp, Design::Trace] {
        let s = serial.eval(131072, d);
        let o = overlapped.eval(131072, d);
        println!("{:<10} serial {:>8.2}  overlapped {:>8.2} tok/s", d.name(), s.tok_s, o.tok_s);
        assert!(s.kv_spill_frac > 0.0);
        assert!(o.tok_s > s.tok_s, "{d:?}: analytic overlap must help post-spill");
    }
    let pre_s = serial.eval(16384, Design::Trace).tok_s;
    let pre_o = overlapped.eval(16384, Design::Trace).tok_s;
    assert!((pre_s - pre_o).abs() < 1e-9, "pre-spill the modes coincide");

    println!("\nOK: overlapped pipeline is bit-identical and strictly faster under spill");
}
