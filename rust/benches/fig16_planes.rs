//! Fig. 16 — plane-level compressibility (ZSTD, 4 KB blocks): the most
//! significant exponent planes dominate the gains for BF16 weights; after
//! FP8/INT4 quantization the per-plane headroom narrows; KV exponent
//! planes benefit further from Mechanism I.

use trace_cxl::bitplane::{plane_len, transpose_to_planes, KvTransform, KvWindow};
use trace_cxl::codec::{compress, CodecKind};
use trace_cxl::formats::{fp8_e4m3_from_f32, int4_pack, int4_quantize, Fmt};
use trace_cxl::gen::{KvGen, WeightGen};
use trace_cxl::util::Rng;

fn per_plane(words: &[u16], bits: usize) -> Vec<f64> {
    let flat = transpose_to_planes(words, bits);
    let pl = plane_len(words.len());
    (0..bits)
        .rev() // MSB first for display
        .map(|i| {
            let row = bits - 1 - i;
            let stream = &flat[row * pl..(row + 1) * pl];
            let c = compress(CodecKind::Zstd, stream);
            stream.len() as f64 / c.len().min(stream.len()) as f64
        })
        .collect()
}

fn print_row(label: &str, fmt: Fmt, ratios: &[f64]) {
    let roles = fmt.plane_roles();
    print!("{label:<18}");
    for (k, r) in ratios.iter().enumerate() {
        let bitpos = fmt.bits() - 1 - k;
        print!(" {}{:>5.2}", &roles.role(bitpos)[..1], r);
    }
    println!();
}

fn main() {
    let mut rng = Rng::new(0xF16);
    let n = 8 * 2048;
    let wgen = WeightGen::default_for(512);
    let w32 = wgen.generate_f32(&mut rng, n);
    let bf16: Vec<u16> = w32.iter().map(|&x| trace_cxl::formats::bf16_from_f32(x)).collect();
    let fp8: Vec<u16> = w32.iter().map(|&x| fp8_e4m3_from_f32(x) as u16).collect();
    let (c4, _) = int4_quantize(&w32, 256);
    let int4: Vec<u16> = int4_pack(&c4).iter().map(|&b| (b & 0xf) as u16).collect();

    println!("# Fig 16: per-plane ZSTD compression ratios (MSB -> LSB; s=sign e=exp m=man)");
    let bf = per_plane(&bf16, 16);
    print_row("BF16 weights", Fmt::Bf16, &bf);
    let f8 = per_plane(&fp8, 8);
    print_row("FP8 weights", Fmt::Fp8E4M3, &f8);
    let i4 = per_plane(&int4, 4);
    print_row("INT4 weights", Fmt::Int4, &i4);

    // KV with and without Mechanism I
    let kv = KvGen::default_for(64).generate(&mut rng, 128);
    let kv_raw = per_plane(&kv, 16);
    let t = KvTransform::forward(&kv, KvWindow::new(128, 64));
    let kv_trace = per_plane(&t.words, 16);
    print_row("BF16 KV (raw)", Fmt::Bf16, &kv_raw);
    print_row("BF16 KV (TRACE)", Fmt::Bf16, &kv_trace);

    // shape assertions
    let top_exp_bf: f64 = bf[1..5].iter().sum::<f64>() / 4.0; // exponent MSB planes
    let man_bf: f64 = bf[10..16].iter().sum::<f64>() / 6.0;
    assert!(top_exp_bf > 3.0 * man_bf, "exponent planes dominate BF16 gains");
    let kv_exp_gain: f64 = kv_trace[1..6].iter().sum::<f64>() / kv_raw[1..6].iter().sum::<f64>();
    assert!(kv_exp_gain > 1.5, "Mechanism I boosts KV exponent planes ({kv_exp_gain:.2}x)");
    let bf_total: f64 = bf.iter().sum::<f64>() / 16.0;
    let i4_total: f64 = i4.iter().sum::<f64>() / 4.0;
    assert!(bf_total > i4_total, "quantized bases have less per-plane headroom");
    println!("\npaper: high-order exponent planes are consistently the most compressible;");
    println!("KV exponent planes benefit further from channel grouping + exponent-delta");
}
