//! Table I — direct lossless compression on the standard word-major layout
//! is weak: LZ4 ≈ 0% on most weights and on all KV; ZSTD only ~17–23% on
//! weights and ~1–7% on KV.
//!
//! Regenerates the table on calibrated tensors for five model shapes
//! (DESIGN.md §Substitutions: checkpoints/corpora replaced by calibrated
//! generators with the same field statistics).

use trace_cxl::codec::{compress, CodecKind};
use trace_cxl::gen::{KvGen, WeightGen};
use trace_cxl::util::bytes::u16s_to_bytes;
use trace_cxl::util::Rng;

fn savings(kind: CodecKind, data: &[u8]) -> f64 {
    let c = compress(kind, data);
    let s = 1.0 - c.len() as f64 / data.len() as f64;
    s.max(0.0) * 100.0
}

fn main() {
    let models: [(&str, usize, usize); 5] = [
        ("LLaMA 3.1 8B", 4096, 1024),
        ("Gemma 2 2B", 2304, 2048),
        ("Mistral 7B", 4096, 1024),
        ("OPT 13B", 5120, 7168),
        ("Mixtral 8x7B", 4096, 1024),
    ];
    let mut rng = Rng::new(0xB1);

    println!("# Table I: footprint reduction under DIRECT lossless compression (word-major)");
    println!("{:<16} {:>10} {:>10} {:>12} {:>12}", "Model", "W LZ4 %", "W ZSTD %", "KV LZ4 %", "KV ZSTD %");
    for (name, d, kv_ch) in models {
        let wgen = WeightGen::default_for(d.min(2048));
        let w = wgen.generate(&mut rng, 64 * 2048);
        let wb = u16s_to_bytes(&w);
        // KV: token-major stream (the arrival order the device sees)
        let kgen = KvGen::default_for(kv_ch.min(128));
        let kv = kgen.generate(&mut rng, 2048);
        let kb = u16s_to_bytes(&kv);
        let w_lz4 = savings(CodecKind::Lz4, &wb);
        let w_zstd = savings(CodecKind::Zstd, &wb);
        let k_lz4 = savings(CodecKind::Lz4, &kb);
        let k_zstd = savings(CodecKind::Zstd, &kb);
        println!("{name:<16} {w_lz4:>10.1} {w_zstd:>10.1} {k_lz4:>12.1} {k_zstd:>12.1}");
        assert!(k_lz4 < 6.0, "KV LZ4 should be ~0%");
        assert!(w_zstd < 35.0, "weight ZSTD modest");
        // Table I reports 0.9-6.5%; Fig 15's GComp blocks reach 17-25% — our
        // calibrated KV sits between the two regimes.
        assert!(k_zstd < 26.0, "KV ZSTD limited under word layout, got {k_zstd}");
    }
    println!("\npaper: weights LZ4 0-18% / ZSTD 17-23%; KV LZ4 0% / ZSTD 0.9-6.5%");
}
