//! Fig. 19 — average model-load latency (device-side DRAM service time for
//! the weight reads of one decode step), per-expert granularity: CXL-Plain
//! word fetch vs TRACE plane-aligned fetch, averaged over decoding steps
//! with changing routing/precision selection.

use trace_cxl::dram::layout::{plane_fetch_requests, unit_scales, word_fetch_requests};
use trace_cxl::dram::{AddrMap, DramConfig, DramSim, EnergyParams};
use trace_cxl::gen::precision::mode_mix;
use trace_cxl::tier::{ChunkGranularity, WeightStore};
use trace_cxl::util::Rng;

fn main() {
    let cfg = DramConfig::paper_default();
    let map = AddrMap::new(cfg);
    let mut rng = Rng::new(0xF19);
    let steps = 8;

    println!("# Fig 19: average model load latency per decode step (ms, scaled chunks)");
    println!("{:<16} {:<6} {:>12} {:>12} {:>10}", "Model", "Base", "Plain (ms)", "TRACE (ms)", "saving %");
    for (model, n_experts, bf16_avg) in [
        ("LLaMA 3.1 8B", 8usize, 11.5f64),
        ("LLaMA 3.1 70B", 8, 10.8),
        ("Mixtral 8x7B", 8, 11.0),
        ("LLaMA-MoE 3.5B", 8, 10.2),
    ] {
        for (base_bits, avg) in [(16usize, bf16_avg), (8, bf16_avg * 0.56), (4, 4.0)] {
            let mix = mode_mix(base_bits, avg);
            let mut store =
                WeightStore::new(&mut rng, 0, ChunkGranularity::Expert, n_experts, &mix, base_bits);
            store.region.elems /= 16; // runtime scaling
            let mut t_word = 0.0;
            let mut t_plane = 0.0;
            for _ in 0..steps {
                let fetches = store.routed(&mut rng, 2);
                let mut s1 = DramSim::new(cfg, EnergyParams::ddr5_4800());
                t_word +=
                    s1.run_frfcfs(word_fetch_requests(&map, store.region, &fetches, 0.0), 16)
                        .finish_ns;
                let mut s2 = DramSim::new(cfg, EnergyParams::ddr5_4800());
                t_plane += s2
                    .run_frfcfs(
                        plane_fetch_requests(
                            &map,
                            store.region,
                            n_experts,
                            &fetches,
                            &unit_scales(base_bits),
                            0.0,
                        ),
                        16,
                    )
                    .finish_ns;
            }
            let (mw, mt) = (t_word / steps as f64 / 1e6, t_plane / steps as f64 / 1e6);
            let saving = 100.0 * (1.0 - mt / mw);
            println!(
                "{:<16} {:<6} {:>12.3} {:>12.3} {:>10.1}",
                model,
                format!("{base_bits}b"),
                mw,
                mt,
                saving
            );
            if base_bits == 16 {
                assert!(saving > 15.0, "BF16 latency saving {saving}");
            }
            assert!(mt <= mw * 1.01, "plane fetch never slower");
        }
    }
    println!("\npaper: up to 30.0% on BF16 (Mixtral 705.90 -> 495.06 ms); quantized bases also gain");
}
