//! Table II — page-level KV policies trade quality for traffic.
//!
//! The paper reports LLaMA-3.1-8B perplexity on BookSum. Offline we use a
//! *quality proxy*: the relative error of the attention output when the KV
//! history is served under each policy (dropped pages masked, quantized
//! pages served through their reduced-precision alias + guard rounding),
//! versus the full-BF16 history — on calibrated KV with a long-tailed page
//! importance profile. The proxy must reproduce the paper's ORDERING:
//! full < dyn-quant(5/5) < dyn-quant(5/3/2) < top-k < sliding-window
//! degradation, while bytes move the other way.

use trace_cxl::bitplane::{DeviceBlock, KvWindow};
use trace_cxl::codec::CodecPolicy;
use trace_cxl::formats::bf16_to_f32;
use trace_cxl::gen::KvGen;
use trace_cxl::tier::{KvPolicy, PageTier, PAGE_TOKENS};
use trace_cxl::util::Rng;

/// Softmax-attention output over the (served) KV history for one query.
fn attn_out(kv: &[f32], channels: usize, tokens: usize, q: &[f32], dead: &[bool]) -> Vec<f32> {
    let hd = channels.min(64);
    let mut scores = vec![f32::NEG_INFINITY; tokens];
    for t in 0..tokens {
        if dead[t] {
            continue;
        }
        let mut s = 0.0;
        for d in 0..hd {
            s += kv[t * channels + d] * q[d];
        }
        scores[t] = s / (hd as f32).sqrt();
    }
    let m = scores.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let mut probs: Vec<f32> = scores.iter().map(|&s| (s - m).exp()).collect();
    let z: f32 = probs.iter().sum();
    for p in probs.iter_mut() {
        *p /= z;
    }
    let mut out = vec![0f32; hd];
    for t in 0..tokens {
        if dead[t] {
            continue;
        }
        for d in 0..hd {
            out[d] += probs[t] * kv[t * channels + d];
        }
    }
    out
}

fn main() {
    let mut rng = Rng::new(0xB2);
    let channels = 64usize;
    let tokens = 20 * PAGE_TOKENS; // 20 pages
    let n_pages = tokens / PAGE_TOKENS;
    let gen = KvGen::default_for(channels);
    let kv_words = gen.generate(&mut rng, tokens);
    let full: Vec<f32> = kv_words.iter().map(|&w| bf16_to_f32(w)).collect();

    // long-tailed page importance (recent + a few early hot pages)
    let mut importance: Vec<f64> = (0..n_pages).map(|i| 1.0 / (1.0 + (n_pages - 1 - i) as f64)).collect();
    importance[1] = 0.9;
    importance[3] = 0.8;

    // average the proxy over several queries to de-noise single-query ties
    let queries: Vec<Vec<f32>> = (0..8)
        .map(|_| (0..channels).map(|_| rng.normal() as f32).collect())
        .collect();
    let bases: Vec<Vec<f32>> = queries
        .iter()
        .map(|q| attn_out(&full, channels, tokens, q, &vec![false; tokens]))
        .collect();

    let policies = [
        KvPolicy::FullKv,
        KvPolicy::SlidingWindow(4 * PAGE_TOKENS),
        KvPolicy::TopK(5),
        KvPolicy::DynamicQuant { bf16: 5, fp8: 3, fp4: 2 },
        KvPolicy::DynamicQuant { bf16: 5, fp8: 5, fp4: 0 },
    ];
    let paper = [10.49, 14.33, 12.49, 11.87, 11.60];

    println!("# Table II: page-level KV policies — quality proxy vs bytes (paper: perplexity)");
    println!("{:<58} {:>12} {:>10} {:>12}", "Policy", "rel.err", "bytes %", "paper ppl");
    let mut errs = Vec::new();
    for (pi, policy) in policies.iter().enumerate() {
        let tiers = policy.assign(&importance);
        // serve each page at its tier through the TRACE device path
        let mut served = full.clone();
        let mut dead = vec![false; tokens];
        for (p, tier) in tiers.iter().enumerate() {
            let s = p * PAGE_TOKENS * channels;
            let e = s + PAGE_TOKENS * channels;
            match tier.view() {
                None => {
                    for d in dead.iter_mut().take((p + 1) * PAGE_TOKENS).skip(p * PAGE_TOKENS) {
                        *d = true;
                    }
                }
                Some(v) if v.is_full() => {}
                Some(v) => {
                    let blk = DeviceBlock::encode_kv(
                        &kv_words[s..e],
                        KvWindow::new(PAGE_TOKENS, channels),
                        CodecPolicy::FastBest,
                    );
                    let words = blk.decode_view(&v).unwrap();
                    for (i, &w) in words.iter().enumerate() {
                        served[s + i] = bf16_to_f32(w);
                    }
                }
            }
            let _ = tier;
        }
        let mut err = 0f32;
        for (q, base) in queries.iter().zip(&bases) {
            let out = attn_out(&served, channels, tokens, q, &dead);
            err += out.iter().zip(base).map(|(a, b)| (a - b).powi(2)).sum::<f32>().sqrt()
                / base.iter().map(|b| b * b).sum::<f32>().sqrt();
        }
        err /= queries.len() as f32;
        let bytes: usize = tiers.iter().map(|t| t.bits()).sum::<usize>() * PAGE_TOKENS * channels / 8;
        let frac = 100.0 * bytes as f64 / (tokens * channels * 2) as f64;
        println!("{:<58} {:>12.4} {:>10.1} {:>12.2}", policy.name(), err, frac, paper[pi]);
        errs.push(err);
        let _ = PageTier::Bf16;
    }
    // ordering assertions (paper Table II shape). The two dynamic-quant
    // variants differ only in the precision of two *low-importance* pages,
    // so the proxy separates them within noise — allow a 5% band (the
    // paper's own gap is 2%: 11.60 vs 11.87).
    assert!(errs[0] < 1e-6, "full KV is exact");
    assert!(errs[4] <= errs[3] * 1.05, "5/5 dyn-quant ~beats 5/3/2");
    assert!(errs[3] < errs[2], "dyn-quant beats top-k");
    assert!(errs[2] < errs[1], "top-k beats sliding window");
    println!("\nordering matches paper: Full < DQ(5/5) < DQ(5/3/2) < TopK < SlidingWindow degradation");
}
