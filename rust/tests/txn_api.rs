//! Transaction-API coverage: precision-view roundtrips across all three
//! `Design`s (Plain / GComp / TRACE), the metadata-cache-miss path, and
//! single-vs-sharded equivalence — everything through `MemDevice` +
//! `SubmissionQueue`, never a concrete method.

use trace_cxl::bitplane::{KvWindow, PrecisionView};
use trace_cxl::codec::CodecPolicy;
use trace_cxl::cxl::{
    CxlDevice, Design, IndexCache, MemDevice, ShardedDevice, SubmissionQueue, Transaction,
    STRIPE_BYTES,
};
use trace_cxl::formats::Fmt;
use trace_cxl::tier::PageTier;
use trace_cxl::util::check::smooth_kv;
use trace_cxl::util::Rng;

fn all_designs(policy: CodecPolicy) -> [CxlDevice; 3] {
    [
        CxlDevice::new(Design::Plain, policy),
        CxlDevice::new(Design::GComp, policy),
        CxlDevice::new(Design::Trace, policy),
    ]
}

fn write_kv(d: &mut dyn MemDevice, addr: u64, kv: &[u16], window: KvWindow) {
    d.submit_one(Transaction::WriteKv { block_addr: addr, words: kv.to_vec(), window }).unwrap();
}

fn read_view(d: &mut dyn MemDevice, addr: u64, view: PrecisionView) -> Vec<u16> {
    d.submit_one(Transaction::ReadView { block_addr: addr, view })
        .unwrap()
        .into_words()
        .unwrap()
}

#[test]
fn precision_view_roundtrips_identical_across_designs() {
    // every tier-ladder view must return bit-identical host-visible words
    // on all three designs (paper §III-D invariant), via the txn queue
    let mut r = Rng::new(811);
    let kv = smooth_kv(&mut r, 32, 64);
    let views = [
        PrecisionView::full(Fmt::Bf16),
        PrecisionView::bf16_mantissa(5, 1),
        PrecisionView::bf16_mantissa(3, 1),
        PrecisionView::bf16_mantissa(3, 0),
        PrecisionView::bf16_mantissa(0, 1),
        PrecisionView::bf16_mantissa(0, 0),
    ];
    for policy in [CodecPolicy::FastBest, CodecPolicy::AllBest] {
        let mut devs = all_designs(policy);
        for d in devs.iter_mut() {
            write_kv(d, 0x0, &kv, KvWindow::new(32, 64));
        }
        for view in views {
            let outs: Vec<Vec<u16>> =
                devs.iter_mut().map(|d| read_view(d, 0x0, view)).collect();
            assert_eq!(outs[0], outs[1], "plain vs gcomp, view {view:?}");
            assert_eq!(outs[0], outs[2], "plain vs trace, view {view:?}");
            if view.is_full() {
                assert_eq!(outs[0], kv, "full view must be lossless");
            }
        }
    }
}

#[test]
fn tier_ladder_views_roundtrip_through_the_queue() {
    // the exact views the page-tier policy issues, batched in one
    // submission and routed back by id
    let mut r = Rng::new(812);
    let kv = smooth_kv(&mut r, 16, 128);
    for mut d in all_designs(CodecPolicy::AllBest) {
        write_kv(&mut d, 0x0, &kv, KvWindow::new(16, 128));
        let mut sq = SubmissionQueue::new();
        let mut ids = Vec::new();
        for tier in [PageTier::Bf16, PageTier::Fp8, PageTier::Fp4] {
            let view = tier.view().unwrap();
            ids.push(sq.submit(Transaction::ReadView { block_addr: 0x0, view }));
        }
        let completions = d.drain(&mut sq);
        assert_eq!(completions.len(), 3);
        for c in completions {
            assert!(ids.contains(&c.id));
            let words = c.words().unwrap();
            assert_eq!(words.len(), kv.len());
        }
    }
}

#[test]
fn metadata_cache_miss_path_charges_and_reports() {
    // a cold/thrashing index cache must surface in stats and in the
    // per-completion latency (one extra DRAM window), on GComp and TRACE
    let mut r = Rng::new(813);
    let kv = smooth_kv(&mut r, 32, 64);
    for design in [Design::GComp, Design::Trace] {
        let mut d = CxlDevice::new(design, CodecPolicy::FastBest);
        d.index_cache = IndexCache::new(2); // tiny: guaranteed conflict misses
        for b in 0..8u64 {
            write_kv(&mut d, b * STRIPE_BYTES, &kv, KvWindow::new(32, 64));
        }
        d.reset_stats();
        let mut sq = SubmissionQueue::new();
        for b in 0..8u64 {
            sq.submit(Transaction::ReadView {
                block_addr: b * STRIPE_BYTES,
                view: PrecisionView::bf16_mantissa(3, 1),
            });
        }
        let completions = d.drain(&mut sq);
        let misses = d.stats().metadata_dram_reads;
        assert!(misses > 0, "{design:?}: tiny cache must miss");
        let with_penalty = completions
            .iter()
            .filter(|c| c.latency.map_or(0, |l| l.meta_miss) > 0)
            .count() as u64;
        assert_eq!(with_penalty, misses, "{design:?}: completions must carry the miss window");
        // and the values still roundtrip identically to a warm device
        let mut warm = CxlDevice::new(design, CodecPolicy::FastBest);
        write_kv(&mut warm, 0x0, &kv, KvWindow::new(32, 64));
        let expect = read_view(&mut warm, 0x0, PrecisionView::bf16_mantissa(3, 1));
        let got = read_view(&mut d, 0x0, PrecisionView::bf16_mantissa(3, 1));
        assert_eq!(got, expect, "{design:?}: miss path must not corrupt data");
    }
}

#[test]
fn partial_plane_ranges_keep_host_visible_equivalence() {
    // §III-D invariant extended to ReadPlanes: for ANY range, every design
    // returns the host words with bits outside the range zeroed — even on
    // KV blocks where TRACE must fetch the delta-coded exponent core to
    // invert exactly
    let mut r = Rng::new(816);
    let kv = smooth_kv(&mut r, 32, 64);
    let ranges: [std::ops::Range<usize>; 5] = [0..7, 7..16, 10..14, 15..16, 0..16];
    for range in ranges {
        let mut outs = Vec::new();
        for mut d in all_designs(CodecPolicy::AllBest) {
            write_kv(&mut d, 0x0, &kv, KvWindow::new(32, 64));
            let words = d
                .submit_one(Transaction::ReadPlanes { block_addr: 0x0, range: range.clone() })
                .unwrap()
                .into_words()
                .unwrap();
            outs.push(words);
        }
        assert_eq!(outs[0], outs[1], "plain vs gcomp, range {range:?}");
        assert_eq!(outs[0], outs[2], "plain vs trace, range {range:?}");
        // and the baseline semantics are plain truncation of the original
        let mut keep: u16 = 0;
        for b in range.clone() {
            keep |= 1 << b;
        }
        let expect: Vec<u16> = kv.iter().map(|&w| w & keep).collect();
        assert_eq!(outs[0], expect, "range {range:?}");
    }
}

#[test]
fn plane_range_reads_scale_bytes_on_trace_only() {
    let mut r = Rng::new(814);
    let kv = smooth_kv(&mut r, 32, 64);
    let mut plain = CxlDevice::new(Design::Plain, CodecPolicy::AllBest);
    let mut trace = CxlDevice::new(Design::Trace, CodecPolicy::AllBest);
    write_kv(&mut plain, 0x0, &kv, KvWindow::new(32, 64));
    write_kv(&mut trace, 0x0, &kv, KvWindow::new(32, 64));
    plain.reset_stats();
    trace.reset_stats();
    // sign + exponent planes only (bit positions 8..16)
    plain.submit_one(Transaction::ReadPlanes { block_addr: 0x0, range: 8..16 }).unwrap();
    trace.submit_one(Transaction::ReadPlanes { block_addr: 0x0, range: 8..16 }).unwrap();
    // Plain serves the full container; TRACE fetches only those planes
    assert_eq!(plain.stats().dram_bytes_read, 4096);
    assert!(trace.stats().dram_bytes_read * 2 < 4096);
}

#[test]
fn sharded_views_match_single_device_views() {
    let mut r = Rng::new(815);
    let kv = smooth_kv(&mut r, 32, 64);
    let mut one = CxlDevice::new(Design::Trace, CodecPolicy::FastBest);
    let mut four = ShardedDevice::new(4, Design::Trace, CodecPolicy::FastBest);
    for b in 0..8u64 {
        write_kv(&mut one, b * STRIPE_BYTES, &kv, KvWindow::new(32, 64));
        write_kv(&mut four, b * STRIPE_BYTES, &kv, KvWindow::new(32, 64));
    }
    for b in 0..8u64 {
        for view in [PrecisionView::full(Fmt::Bf16), PrecisionView::bf16_mantissa(3, 1)] {
            let a = read_view(&mut one, b * STRIPE_BYTES, view);
            let d = read_view(&mut four, b * STRIPE_BYTES, view);
            assert_eq!(a, d, "block {b} view {view:?}");
        }
    }
    assert_eq!(one.stats().dram_bytes_read, four.stats().dram_bytes_read);
}
