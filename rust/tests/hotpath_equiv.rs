//! Zero-allocation / pool / decoded-plane-cache / codec-lane equivalence
//! matrix.
//!
//! The PR-5 data-path rebuild (`BlockScratch`, batch worker pool, decoded
//! plane cache) and the PR-7 intra-block codec lanes are pure *host
//! wall-clock* optimizations. These tests are the gate that no modeled
//! number moved:
//!
//! * **Device level** — per-transaction [`Completion`] fields (payload
//!   words, byte-traffic deltas, pipeline latency, `issued_ns`,
//!   `ready_at_ns`, serving shard) are bit-identical across
//!   `{pool 1, 4} × {cache on, off} × {codec lanes 1, 4}` for every
//!   design `{Plain, GComp, Trace}`, on batched and one-at-a-time
//!   submission.
//! * **Engine level** — tokens and aggregate device traffic are
//!   bit-identical across the same matrix on both the serial and the
//!   overlapped-prefetch engines (the mock backend decodes from KV
//!   content, so a single wrong scattered value would change tokens).

use trace_cxl::bitplane::{KvWindow, PrecisionView};
use trace_cxl::codec::CodecPolicy;
use trace_cxl::coordinator::{Engine, EngineConfig};
use trace_cxl::cxl::{
    Completion, CxlDevice, Design, DeviceStats, MemDevice, Payload, ShardedDevice,
    SubmissionQueue, Transaction, STRIPE_BYTES,
};
use trace_cxl::formats::Fmt;
use trace_cxl::runtime::MockBackend;
use trace_cxl::util::check::smooth_kv;
use trace_cxl::util::Rng;

/// The (pool, cache, codec-lane) configurations under test; index 0 is the
/// reference (serial, cache off, one lane — the PR-4 behavior). The last
/// entry stacks every mechanism at once: across-block pool fan-out AND the
/// cache AND intra-block lanes (where the nesting guard keeps lanes inline
/// on pooled batches).
const CONFIGS: [(usize, usize, usize); 6] =
    [(1, 0, 1), (4, 0, 1), (1, 128, 1), (4, 128, 1), (1, 0, 4), (4, 128, 4)];

fn assert_completions_identical(tag: &str, base: &[Completion], got: &[Completion]) {
    assert_eq!(base.len(), got.len(), "{tag}: completion count");
    for (b, g) in base.iter().zip(got.iter()) {
        let t = format!("{tag} txn={} kind={}", b.id, b.kind);
        assert_eq!(g.id, b.id, "{t}: id order");
        assert_eq!(g.kind, b.kind, "{t}");
        assert_eq!(g.shard, b.shard, "{t}: serving shard");
        assert_eq!(g.stats, b.stats, "{t}: byte-traffic delta");
        assert_eq!(g.latency_ns(), b.latency_ns(), "{t}: pipeline latency");
        assert_eq!(g.issued_ns, b.issued_ns, "{t}: issue stamp");
        assert_eq!(g.ready_at_ns, b.ready_at_ns, "{t}: ready-at stamp");
        assert_eq!(g.is_read, b.is_read, "{t}");
        match (&b.result, &g.result) {
            (Ok(Payload::Words(x)), Ok(Payload::Words(y))) => assert_eq!(x, y, "{t}: payload"),
            (Ok(Payload::Written), Ok(Payload::Written)) => {}
            (Err(_), Err(_)) => {}
            _ => panic!("{t}: result shape diverged"),
        }
    }
}

/// A workload that exercises every transaction kind, a same-batch
/// write→read hazard, an error path, and repeated (cacheable) reads.
fn device_workload(dev: &mut dyn MemDevice, kv: &[u16], kv2: &[u16]) -> Vec<Completion> {
    let w = KvWindow::new(32, 64);
    let mut all = Vec::new();
    // batched writes across 8 stripe-aligned blocks
    let mut sq = SubmissionQueue::new();
    for b in 0..8u64 {
        sq.submit(Transaction::WriteKv {
            block_addr: b * STRIPE_BYTES,
            words: kv.to_vec(),
            window: w,
        });
    }
    all.extend(dev.drain_at(&mut sq, 1.0));
    // two read rounds (second hits the cache when enabled) + hazards
    for round in 0..2 {
        let mut sq = SubmissionQueue::new();
        for b in 0..8u64 {
            let addr = b * STRIPE_BYTES;
            sq.submit(Transaction::ReadFull { block_addr: addr });
            match b % 3 {
                0 => {
                    sq.submit(Transaction::ReadView {
                        block_addr: addr,
                        view: PrecisionView::bf16_mantissa(3, 1),
                    });
                }
                1 => {
                    sq.submit(Transaction::ReadPlanes { block_addr: addr, range: 9..16 });
                }
                _ => {}
            }
        }
        if round == 1 {
            // write→read hazard inside one batch + an error completion
            sq.submit(Transaction::WriteKv {
                block_addr: 0,
                words: kv2.to_vec(),
                window: w,
            });
            sq.submit(Transaction::ReadFull { block_addr: 0 });
            sq.submit(Transaction::ReadFull { block_addr: 0xdead_0000 });
        }
        all.extend(dev.drain_at(&mut sq, 10.0 + round as f64));
    }
    // one-at-a-time path (execute_at) + free + double-free error
    all.push(dev.execute_at(9000, Transaction::ReadFull { block_addr: STRIPE_BYTES }, 99.0));
    all.push(dev.execute_at(9001, Transaction::Free { block_addr: STRIPE_BYTES }, 99.5));
    all.push(dev.execute_at(9002, Transaction::Free { block_addr: STRIPE_BYTES }, 99.6));
    all
}

fn run_single(
    design: Design,
    pool: usize,
    cache: usize,
    lanes: usize,
) -> (Vec<Completion>, DeviceStats) {
    let mut r = Rng::new(0x5EED);
    let kv = smooth_kv(&mut r, 32, 64);
    let kv2 = smooth_kv(&mut r, 32, 64);
    let mut d = CxlDevice::new(design, CodecPolicy::AllBest);
    d.set_pool(pool);
    d.set_decode_cache(cache);
    d.set_codec_lanes(lanes);
    let cs = device_workload(&mut d, &kv, &kv2);
    let stats = d.stats();
    (cs, stats)
}

fn run_sharded(
    design: Design,
    pool: usize,
    cache: usize,
    lanes: usize,
) -> (Vec<Completion>, DeviceStats) {
    let mut r = Rng::new(0x5EED);
    let kv = smooth_kv(&mut r, 32, 64);
    let kv2 = smooth_kv(&mut r, 32, 64);
    let mut d = ShardedDevice::new(4, design, CodecPolicy::AllBest);
    d.set_pool(pool);
    d.set_decode_cache(cache);
    d.set_codec_lanes(lanes);
    let cs = device_workload(&mut d, &kv, &kv2);
    let stats = d.stats();
    (cs, stats)
}

#[test]
fn per_txn_completions_identical_single_device() {
    for design in [Design::Plain, Design::GComp, Design::Trace] {
        let (p0, c0, l0) = CONFIGS[0];
        let (base, base_stats) = run_single(design, p0, c0, l0);
        for &(pool, cache, lanes) in &CONFIGS[1..] {
            let tag = format!("{design:?} pool={pool} cache={cache} lanes={lanes}");
            let (cs, stats) = run_single(design, pool, cache, lanes);
            assert_eq!(stats, base_stats, "{tag}: cumulative device counters");
            assert_completions_identical(&tag, &base, &cs);
        }
    }
}

#[test]
fn per_txn_completions_identical_sharded() {
    for design in [Design::Plain, Design::GComp, Design::Trace] {
        let (p0, c0, l0) = CONFIGS[0];
        let (base, base_stats) = run_sharded(design, p0, c0, l0);
        for &(pool, cache, lanes) in &CONFIGS[1..] {
            let tag = format!("sharded {design:?} pool={pool} cache={cache} lanes={lanes}");
            let (cs, stats) = run_sharded(design, pool, cache, lanes);
            assert_eq!(stats, base_stats, "{tag}: cumulative device counters");
            assert_completions_identical(&tag, &base, &cs);
        }
    }
}

#[test]
fn cache_actually_hits_on_the_repeat_round() {
    // guard against the matrix passing vacuously with a cache that never
    // engages: the second read round over plane/compressed blocks must hit
    let mut r = Rng::new(0x5EED);
    let kv = smooth_kv(&mut r, 32, 64);
    let kv2 = smooth_kv(&mut r, 32, 64);
    for design in [Design::GComp, Design::Trace] {
        let mut d = CxlDevice::new(design, CodecPolicy::AllBest);
        d.set_pool(4);
        d.set_decode_cache(128);
        device_workload(&mut d, &kv, &kv2);
        let (hits, misses, live) = d.decode_cache_stats();
        assert!(hits > 0, "{design:?}: cache never hit (misses={misses})");
        assert!(live > 0, "{design:?}: cache holds entries");
    }
}

struct EngineOut {
    tokens: Vec<Vec<u32>>,
    stats: DeviceStats,
    spilled: u64,
    model_ns: f64,
}

fn run_engine(
    design: Design,
    overlap: bool,
    shards: usize,
    pool: usize,
    cache: usize,
    lanes: usize,
) -> EngineOut {
    let mut e = Engine::new(
        MockBackend::tiny(),
        EngineConfig {
            design,
            hbm_kv_bytes: 0, // everything spills: maximal device traffic
            shards,
            overlap,
            pool_threads: pool,
            decode_cache_blocks: cache,
            codec_lanes: lanes,
            ..Default::default()
        },
    );
    e.submit(vec![1, 2, 3, 4], 60);
    e.submit(vec![5, 6], 60);
    e.run_to_completion(300).unwrap();
    let mut rs = e.take_responses();
    rs.sort_by_key(|r| r.id);
    EngineOut {
        tokens: rs.into_iter().map(|r| r.tokens).collect(),
        stats: e.device.stats(),
        spilled: e.metrics.pages_spilled,
        model_ns: e.metrics.model_ns,
    }
}

#[test]
fn engine_tokens_and_traffic_identical_across_matrix() {
    // shards fixed at 4 (the fleet-pool case); the single-device per-txn
    // matrix above covers shards=1 at finer granularity
    let shards = 4usize;
    for design in [Design::Plain, Design::GComp, Design::Trace] {
        for overlap in [false, true] {
            let (p0, c0, l0) = CONFIGS[0];
            let base = run_engine(design, overlap, shards, p0, c0, l0);
            assert!(base.spilled > 0, "{design:?}: workload must spill");
            for &(pool, cache, lanes) in &CONFIGS[1..] {
                let tag = format!(
                    "{design:?} overlap={overlap} shards={shards} pool={pool} cache={cache} lanes={lanes}"
                );
                let got = run_engine(design, overlap, shards, pool, cache, lanes);
                assert_eq!(got.tokens, base.tokens, "{tag}: tokens");
                assert_eq!(got.stats, base.stats, "{tag}: aggregate device traffic");
                assert_eq!(got.model_ns, base.model_ns, "{tag}: model time");
            }
        }
    }
}

#[test]
fn weights_roundtrip_identical_across_matrix() {
    // WriteWeights / full + plane reads on all designs, bit-exact payloads
    let mut r = Rng::new(77);
    let words: Vec<u16> = (0..2048).map(|_| r.next_u32() as u16).collect();
    for design in [Design::Plain, Design::GComp, Design::Trace] {
        let mut outs = Vec::new();
        for &(pool, cache, lanes) in &CONFIGS {
            let mut d = CxlDevice::new(design, CodecPolicy::FastBest);
            d.set_pool(pool);
            d.set_decode_cache(cache);
            d.set_codec_lanes(lanes);
            let mut sq = SubmissionQueue::new();
            sq.submit(Transaction::WriteWeights {
                block_addr: 0x40_0000,
                words: words.clone(),
                fmt: Fmt::Bf16,
            });
            sq.submit(Transaction::ReadFull { block_addr: 0x40_0000 });
            sq.submit(Transaction::ReadPlanes { block_addr: 0x40_0000, range: 0..16 });
            sq.submit(Transaction::ReadPlanes { block_addr: 0x40_0000, range: 0..16 });
            let cs = d.drain_at(&mut sq, 0.0);
            let payloads: Vec<Vec<u16>> = cs
                .into_iter()
                .skip(1)
                .map(|c| c.result.unwrap().into_words().unwrap())
                .collect();
            assert_eq!(payloads[0], words, "{design:?}: lossless readback");
            assert_eq!(payloads[1], words, "{design:?}: full plane range == full read");
            outs.push(payloads);
        }
        assert!(outs.windows(2).all(|w| w[0] == w[1]), "{design:?}: matrix identical");
    }
}
