//! Scheduler-API equivalence and preemption save/restore regressions.
//!
//! The engine was redesigned around a pluggable `SchedulerPolicy`; these
//! tests pin the redesign's safety net:
//!
//! * `Fcfs` (the default) must reproduce the pre-scheduler engine
//!   bit-identically — same tokens AND same aggregate device traffic —
//!   whether it is selected by config, injected as a boxed policy, or
//!   simply left as the default, across device designs, shard counts, and
//!   the overlapped pipeline. (The untouched legacy suites —
//!   `engine.rs` unit tests, `tests/overlap_equiv.rs`,
//!   `tests/integration.rs` — additionally pin the absolute legacy
//!   behaviors this equivalence is anchored to.)
//! * Open-loop admission must gate on model-time arrivals and keep FIFO
//!   order under `Fcfs`.
//! * A preempt→resume roundtrip through the device (save the victim's
//!   KV, free its slot, restore later) must be BF16-lossless: a request
//!   preempted and re-admitted in the same step loses no decode step and
//!   must emit exactly the token stream of an uninterrupted run, across
//!   KV policies, shard counts, HBM budgets, and both pipelines — and
//!   the device must drain to zero blocks when everything finishes.

use trace_cxl::coordinator::{
    Engine, EngineConfig, EngineEvent, Fcfs, SchedKind, SchedPlan, SchedView, SchedulerPolicy,
    SlaClass,
};
use trace_cxl::cxl::{Design, DeviceStats, MemDevice};
use trace_cxl::runtime::MockBackend;
use trace_cxl::tier::KvPolicy;

struct RunOut {
    tokens: Vec<Vec<u32>>,
    stats: DeviceStats,
    spilled: u64,
}

fn collect(e: &mut Engine<MockBackend>) -> RunOut {
    let mut rs = e.take_responses();
    rs.sort_by_key(|r| r.id);
    RunOut {
        tokens: rs.into_iter().map(|r| r.tokens).collect(),
        stats: e.device.stats(),
        spilled: e.metrics.pages_spilled,
    }
}

fn workload(e: &mut Engine<MockBackend>, via_submit_at: bool) {
    if via_submit_at {
        e.submit_at(vec![1, 2, 3, 4], 60, 0.0, SlaClass::Batch);
        e.submit_at(vec![5, 6], 60, 0.0, SlaClass::Batch);
        e.submit_at(vec![7, 8, 9], 40, 0.0, SlaClass::Batch);
    } else {
        e.submit(vec![1, 2, 3, 4], 60);
        e.submit(vec![5, 6], 60);
        e.submit(vec![7, 8, 9], 40);
    }
}

#[test]
fn fcfs_is_identical_across_construction_paths_and_designs() {
    for design in [Design::Plain, Design::GComp, Design::Trace] {
        for shards in [1usize, 4] {
            for overlap in [false, true] {
                let cfg = EngineConfig {
                    design,
                    hbm_kv_bytes: 0,
                    shards,
                    overlap,
                    ..Default::default()
                };
                let tag = format!("{design:?} shards={shards} overlap={overlap}");

                // 1) default config (sched = Fcfs), legacy submit()
                let mut a = Engine::new(MockBackend::tiny(), cfg.clone());
                workload(&mut a, false);
                a.run_to_completion(500).unwrap();
                let a = collect(&mut a);
                assert!(a.spilled > 0, "{tag}: workload must spill");

                // 2) explicit SchedKind::Fcfs, open-loop submit_at(t=0)
                let mut b = Engine::new(
                    MockBackend::tiny(),
                    EngineConfig { sched: SchedKind::Fcfs, ..cfg.clone() },
                );
                workload(&mut b, true);
                b.run_to_completion(500).unwrap();
                let b = collect(&mut b);

                // 3) Fcfs injected through the pluggable-policy seam
                let mut c =
                    Engine::with_scheduler(MockBackend::tiny(), cfg.clone(), Box::new(Fcfs));
                workload(&mut c, false);
                c.run_to_completion(500).unwrap();
                let c = collect(&mut c);

                assert_eq!(a.tokens, b.tokens, "{tag}: submit vs submit_at tokens");
                assert_eq!(a.stats, b.stats, "{tag}: submit vs submit_at traffic");
                assert_eq!(a.tokens, c.tokens, "{tag}: built-in vs injected tokens");
                assert_eq!(a.stats, c.stats, "{tag}: built-in vs injected traffic");
            }
        }
    }
}

#[test]
fn fcfs_admission_order_is_fifo_and_steps_nondecreasing() {
    let mut e = Engine::new(MockBackend::tiny(), EngineConfig::default());
    for i in 0..6u32 {
        e.submit(vec![i + 1], 5);
    }
    e.run_to_completion(500).unwrap();
    assert_eq!(e.take_responses().len(), 6);
    let events = e.poll_events();
    let admitted: Vec<u64> = events
        .iter()
        .filter_map(|ev| match ev {
            EngineEvent::Admitted { seq, .. } => Some(*seq),
            _ => None,
        })
        .collect();
    assert_eq!(admitted, vec![0, 1, 2, 3, 4, 5], "FCFS admits in submission order");
    let times: Vec<f64> = events
        .iter()
        .filter_map(|ev| match ev {
            EngineEvent::Admitted { at_ns, .. } => Some(*at_ns),
            _ => None,
        })
        .collect();
    for w in times.windows(2) {
        assert!(w[1] >= w[0]);
    }
}

#[test]
fn sjf_admits_shortest_remaining_first() {
    let mut e = Engine::new(
        MockBackend::tiny(),
        EngineConfig { sched: SchedKind::Priority, ..Default::default() },
    );
    assert_eq!(e.scheduler_name(), "priority");
    e.set_scheduler(SchedKind::Sjf.build());
    assert_eq!(e.scheduler_name(), "sjf");
    // submission order: 40, 5, 30, 8 decode tokens; two slots
    e.submit(vec![1], 40);
    e.submit(vec![2], 5);
    e.submit(vec![3], 30);
    e.submit(vec![4], 8);
    e.run_to_completion(500).unwrap();
    assert_eq!(e.metrics.requests_finished, 4);
    let admitted: Vec<u64> = e
        .poll_events()
        .iter()
        .filter_map(|ev| match ev {
            EngineEvent::Admitted { seq, .. } => Some(*seq),
            _ => None,
        })
        .collect();
    // first wave: the two shortest (5 and 8); then 30, then 40
    assert_eq!(admitted, vec![1, 3, 2, 0], "SJF admission order");
}

#[test]
fn open_loop_arrivals_gate_admission_in_fifo_order() {
    let mut e = Engine::new(MockBackend::tiny(), EngineConfig::default());
    // second request arrives long after the first finishes: the engine
    // must idle-jump, not busy-spin, and must not admit early
    let late = 10_000_000.0; // 10 ms
    e.submit_at(vec![1, 2], 6, 0.0, SlaClass::Batch);
    e.submit_at(vec![3, 4], 6, late, SlaClass::Interactive);
    e.run_to_completion(500).unwrap();
    assert_eq!(e.metrics.requests_finished, 2);
    assert!(e.metrics.idle_jumps >= 1, "the gap must be jumped, not spun");
    let events = e.poll_events();
    let admissions: Vec<(u64, f64)> = events
        .iter()
        .filter_map(|ev| match ev {
            EngineEvent::Admitted { seq, at_ns, .. } => Some((*seq, *at_ns)),
            _ => None,
        })
        .collect();
    assert_eq!(admissions.len(), 2);
    assert_eq!(admissions[0].0, 0);
    assert_eq!(admissions[1].0, 1);
    assert!(admissions[1].1 >= late, "no admission before arrival");
    // queue delays were recorded and are non-negative
    assert_eq!(e.metrics.queue_delay_ns.len(), 2);
    assert!(e.metrics.queue_delay_ns.iter().all(|&d| d >= 0.0));
    // per-class accounting landed in both buckets
    assert_eq!(e.metrics.ttft_class(SlaClass::Batch).n, 1);
    assert_eq!(e.metrics.ttft_class(SlaClass::Interactive).n, 1);
}

/// FCFS admissions plus exactly one forced preempt-and-readmit of the
/// first running slot at plan call `at` — the victim's KV round-trips
/// through the device within a single step, so no decode step is lost
/// and tokens must match an uninterrupted run bit-for-bit.
struct PreemptResumeOnce {
    calls: u64,
    at: u64,
}

impl SchedulerPolicy for PreemptResumeOnce {
    fn name(&self) -> &'static str {
        "preempt-resume-once"
    }

    fn plan(&mut self, view: &SchedView<'_>) -> SchedPlan {
        self.calls += 1;
        let mut plan = SchedPlan {
            preempt: Vec::new(),
            admit: view.queued.iter().take(view.free_slots).map(|q| q.seq).collect(),
        };
        if self.calls == self.at {
            if let Some(victim) = view.running.iter().find(|s| s.decoding) {
                plan.preempt.push(victim.seq);
                plan.admit.push(victim.seq);
            }
        }
        plan
    }
}

#[test]
fn preempt_resume_roundtrip_is_token_identical_and_drains_device() {
    // one request long enough to hold HBM pages, spilled pages, and a
    // partial live page at the preemption point (pos = 8 + 29 = 37)
    for policy in [KvPolicy::FullKv, KvPolicy::DynamicQuant { bf16: 2, fp8: 2, fp4: 30 }] {
        for shards in [1usize, 4] {
            for hbm in [0u64, 1024, 2048] {
                for overlap in [false, true] {
                    let cfg = EngineConfig {
                        hbm_kv_bytes: hbm,
                        policy,
                        shards,
                        overlap,
                        ..Default::default()
                    };
                    let tag =
                        format!("{policy:?} shards={shards} hbm={hbm} overlap={overlap}");

                    let mut base = Engine::new(MockBackend::tiny(), cfg.clone());
                    base.submit(vec![1, 2, 3, 4, 5, 6, 7, 8], 50);
                    base.run_to_completion(300).unwrap();
                    let base_tokens = base.take_responses().pop().unwrap().tokens;
                    assert_eq!(base.metrics.preemptions, 0);

                    let mut e = Engine::with_scheduler(
                        MockBackend::tiny(),
                        cfg,
                        Box::new(PreemptResumeOnce { calls: 0, at: 30 }),
                    );
                    e.submit(vec![1, 2, 3, 4, 5, 6, 7, 8], 50);
                    e.run_to_completion(300).unwrap();
                    let tokens = e.take_responses().pop().unwrap().tokens;

                    assert_eq!(tokens, base_tokens, "{tag}: save/restore must be lossless");
                    assert_eq!(e.metrics.preemptions, 1, "{tag}");
                    assert_eq!(e.metrics.resumes, 1, "{tag}");
                    assert!(e.metrics.restore_bytes > 0, "{tag}: restore reads the device");
                    // the save wrote extra pages the baseline never did
                    assert!(
                        e.metrics.pages_spilled > base.metrics.pages_spilled,
                        "{tag}: preemption must spill the resident pages"
                    );
                    assert!(
                        e.device.stats().dram_bytes_written
                            > base.device.stats().dram_bytes_written,
                        "{tag}: save traffic must hit the device"
                    );
                    // lifecycle events fired in order
                    let events = e.poll_events();
                    let p = events
                        .iter()
                        .position(|ev| matches!(ev, EngineEvent::Preempted { .. }))
                        .expect("preempted event");
                    let r = events
                        .iter()
                        .position(|ev| matches!(ev, EngineEvent::Resumed { .. }))
                        .expect("resumed event");
                    assert!(p < r, "{tag}: preempt precedes resume");
                    // everything finished: the device holds no dead KV
                    assert_eq!(e.device.len(), 0, "{tag}: device must drain");
                    assert_eq!(e.pager.pages.len(), 0, "{tag}: pager must drain");
                }
            }
        }
    }
}

#[test]
fn priority_class_preempts_batch_for_late_interactive_and_cuts_ttft() {
    let run = |kind: SchedKind| -> (f64, u64, u64) {
        let mut e = Engine::new(
            MockBackend::tiny(),
            EngineConfig { hbm_kv_bytes: 0, sched: kind, ..Default::default() },
        );
        // two long batch jobs occupy both slots from t=0...
        e.submit_at(vec![1, 2, 3, 4], 60, 0.0, SlaClass::Batch);
        e.submit_at(vec![5, 6], 60, 0.0, SlaClass::Batch);
        // ...and two short interactive requests arrive mid-flight
        e.submit_at(vec![7, 8], 8, 30_000.0, SlaClass::Interactive);
        e.submit_at(vec![9], 8, 40_000.0, SlaClass::Interactive);
        e.run_to_completion(1000).unwrap();
        assert_eq!(e.metrics.requests_finished, 4);
        assert_eq!(e.device.len(), 0, "device must drain after resumes");
        assert_eq!(e.metrics.ttft_class(SlaClass::Interactive).n, 2);
        (
            e.metrics.ttft_class(SlaClass::Interactive).max,
            e.metrics.preemptions,
            e.metrics.resumes,
        )
    };
    let (fcfs_ttft, fcfs_preempt, _) = run(SchedKind::Fcfs);
    let (prio_ttft, prio_preempt, prio_resume) = run(SchedKind::Priority);
    assert_eq!(fcfs_preempt, 0, "FCFS never preempts");
    assert!(prio_preempt >= 1, "interactive arrivals must preempt batch slots");
    assert_eq!(prio_resume, prio_preempt, "every victim must resume and finish");
    assert!(
        prio_ttft < fcfs_ttft,
        "priority must cut interactive TTFT (priority {prio_ttft} vs fcfs {fcfs_ttft})"
    );
}
