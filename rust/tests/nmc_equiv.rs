//! NMC offload equivalence matrix (ISSUE 8 acceptance).
//!
//! Core invariant: enabling the near-memory offload planner changes
//! *when and how many bytes move*, never *which tokens come out*. The
//! device's KV rows are a lossless BF16 image of the host's
//! authoritative `slot.kv`, and offload substitutes only full-precision
//! spilled fetches, so across every device design, shard count, and
//! pipeline mode the tokens must be bit-identical offload-on vs.
//! offload-off. Host tuning knobs (decode worker pool, codec lanes) are
//! wall-clock-only and must not perturb any modeled quantity.

use trace_cxl::coordinator::{Engine, EngineConfig};
use trace_cxl::cxl::{Design, DeviceStats, MemDevice};
use trace_cxl::runtime::MockBackend;

struct Run {
    tokens: Vec<Vec<u32>>,
    stats: DeviceStats,
    model_ns: u64,
    offloads: u64,
    saved: u64,
    stale: u64,
}

fn run(cfg: EngineConfig) -> Run {
    let mut e = Engine::new(MockBackend::tiny(), cfg);
    e.submit(vec![1, 2, 3, 4, 5, 6, 7, 8], 72);
    e.submit(vec![9, 10, 11], 72);
    e.run_to_completion(400).unwrap();
    let mut rs = e.take_responses();
    rs.sort_by_key(|r| r.id);
    Run {
        tokens: rs.into_iter().map(|r| r.tokens).collect(),
        stats: e.device.stats(),
        model_ns: e.metrics.model_ns.to_bits(),
        offloads: e.metrics.nmc_offloads,
        saved: e.metrics.link_bytes_saved,
        stale: e.metrics.prefetch_stale,
    }
}

fn cfg(design: Design, shards: usize, overlap: bool, nmc: bool) -> EngineConfig {
    // hbm_kv_bytes = 0: every page spills, so the fetch planner sees
    // offload candidates on every step
    EngineConfig { design, shards, overlap, nmc, hbm_kv_bytes: 0, ..Default::default() }
}

#[test]
fn tokens_are_bit_identical_offload_on_vs_off_across_the_matrix() {
    let mut any_offloads = false;
    for design in [Design::Plain, Design::GComp, Design::Trace] {
        for shards in [1usize, 4] {
            for overlap in [false, true] {
                let tag = format!("{design:?} shards={shards} overlap={overlap}");
                let off = run(cfg(design, shards, overlap, false));
                let on = run(cfg(design, shards, overlap, true));
                assert_eq!(off.tokens, on.tokens, "{tag}: offload changed tokens");
                assert_eq!(off.offloads, 0, "{tag}: planner must stay cold when disabled");
                assert_eq!(off.stats.nmc_bytes_scanned, 0, "{tag}");
                if on.offloads > 0 {
                    any_offloads = true;
                    assert!(on.saved > 0, "{tag}: offloads must bank link savings");
                    assert!(on.stats.nmc_bytes_scanned > 0, "{tag}");
                    assert!(
                        on.stats.link_bytes_out < off.stats.link_bytes_out,
                        "{tag}: reduced payloads must shrink host-link reads \
                         (on={} off={})",
                        on.stats.link_bytes_out,
                        off.stats.link_bytes_out
                    );
                } else {
                    // the planner declined every candidate (e.g. Plain
                    // never warms the decode cache): with zero offloads
                    // the two runs must coincide exactly
                    assert_eq!(on.stats, off.stats, "{tag}: idle planner perturbed traffic");
                    assert_eq!(on.model_ns, off.model_ns, "{tag}: idle planner perturbed time");
                }
                if overlap {
                    assert_eq!(on.stale, 0, "{tag}: offload decision must prefetch exactly");
                }
            }
        }
    }
    assert!(any_offloads, "some matrix point must actually offload");
}

#[test]
fn plain_design_never_offloads() {
    // Plain stores raw words and never populates the decoded-plane
    // cache, so its hit rate stays 0 and the cost model always prefers
    // the full link transfer at these rates
    for shards in [1usize, 4] {
        let on = run(cfg(Design::Plain, shards, false, true));
        assert_eq!(on.offloads, 0, "shards={shards}");
        assert_eq!(on.stats.nmc_bytes_scanned, 0, "shards={shards}");
    }
}

#[test]
fn pool_and_codec_lane_knobs_never_perturb_offload_results() {
    let base = run(cfg(Design::Trace, 4, true, true));
    assert!(base.offloads > 0, "base config must offload");
    for (pool, lanes) in [(4usize, 1usize), (1, 4), (4, 4)] {
        let mut c = cfg(Design::Trace, 4, true, true);
        c.pool_threads = pool;
        c.codec_lanes = lanes;
        let r = run(c);
        let tag = format!("pool={pool} lanes={lanes}");
        assert_eq!(r.tokens, base.tokens, "{tag}: tokens diverged");
        assert_eq!(r.stats, base.stats, "{tag}: device traffic diverged");
        assert_eq!(r.model_ns, base.model_ns, "{tag}: model time diverged");
        assert_eq!(r.offloads, base.offloads, "{tag}: offload count diverged");
        assert_eq!(r.saved, base.saved, "{tag}: link savings diverged");
    }
}
