//! Serial vs overlapped-pipeline equivalence matrix, and the
//! stale-prefetch fence regression test.
//!
//! The overlapped engine prefetches step N+1's spilled pages during step
//! N's compute. That is a pure *timing* optimization: across every device
//! design, shard count, and page-tier policy it must produce bit-identical
//! tokens AND identical aggregate device byte traffic (the mock backend's
//! decode reads the KV content, so a single wrong scattered value changes
//! tokens). Model time, however, must strictly improve whenever there is
//! spill traffic to hide.

use trace_cxl::coordinator::{Engine, EngineConfig};
use trace_cxl::cxl::{Design, DeviceStats, MemDevice};
use trace_cxl::runtime::MockBackend;
use trace_cxl::tier::KvPolicy;

struct RunOut {
    tokens: Vec<Vec<u32>>,
    stats: DeviceStats,
    spilled: u64,
    model_ns: f64,
    prefetch_hits: u64,
    prefetch_stale: u64,
}

fn run(design: Design, shards: usize, overlap: bool, policy: KvPolicy) -> RunOut {
    let mut e = Engine::new(
        MockBackend::tiny(),
        EngineConfig { design, hbm_kv_bytes: 0, shards, overlap, policy, ..Default::default() },
    );
    e.submit(vec![1, 2, 3, 4], 60);
    e.submit(vec![5, 6], 60);
    e.run_to_completion(300).unwrap();
    let mut rs = e.take_responses();
    rs.sort_by_key(|r| r.id);
    RunOut {
        tokens: rs.into_iter().map(|r| r.tokens).collect(),
        stats: e.device.stats(),
        spilled: e.metrics.pages_spilled,
        model_ns: e.metrics.model_ns,
        prefetch_hits: e.metrics.prefetch_hits,
        prefetch_stale: e.metrics.prefetch_stale,
    }
}

#[test]
fn overlap_matrix_bit_identical_across_designs_and_shards() {
    for design in [Design::Plain, Design::GComp, Design::Trace] {
        for shards in [1usize, 4] {
            let serial = run(design, shards, false, KvPolicy::FullKv);
            let over = run(design, shards, true, KvPolicy::FullKv);
            let tag = format!("{design:?} shards={shards}");
            assert!(serial.spilled > 0, "{tag}: workload must spill");
            assert_eq!(serial.tokens, over.tokens, "{tag}: tokens must be bit-identical");
            assert_eq!(serial.stats, over.stats, "{tag}: aggregate device traffic must match");
            assert!(over.prefetch_hits > 0, "{tag}: pipeline must actually prefetch");
            assert_eq!(over.prefetch_stale, 0, "{tag}: steady state has no stale prefetches");
            assert!(
                over.model_ns < serial.model_ns,
                "{tag}: overlap must strictly help ({} vs {} ns)",
                over.model_ns,
                serial.model_ns
            );
        }
    }
}

#[test]
fn overlap_matrix_with_tier_ladder_policy() {
    // DynamicQuant shifts page tiers every time a page commits, so the
    // prefetcher must predict next step's ranking, not reuse this step's
    let policy = KvPolicy::DynamicQuant { bf16: 2, fp8: 2, fp4: 30 };
    for shards in [1usize, 4] {
        let serial = run(Design::Trace, shards, false, policy);
        let over = run(Design::Trace, shards, true, policy);
        let tag = format!("dynquant shards={shards}");
        assert!(serial.spilled > 0, "{tag}");
        assert_eq!(serial.tokens, over.tokens, "{tag}: tokens");
        assert_eq!(serial.stats, over.stats, "{tag}: traffic");
        assert_eq!(over.prefetch_stale, 0, "{tag}: tier shifts must be predicted, not fenced");
        assert!(over.model_ns < serial.model_ns, "{tag}: model time");
    }
}

#[test]
fn overlap_matrix_with_page_drops() {
    // an aggressive ladder ({1,1,1}) pushes the coldest page off the end
    // once a sequence holds 5 pages: its last reduced-precision scatter
    // must be restored from the authoritative copy in BOTH pipelines, and
    // the prefetcher must predict the drop instead of issuing a dead read
    let policy = KvPolicy::DynamicQuant { bf16: 1, fp8: 1, fp4: 1 };
    let run80 = |overlap: bool| {
        let mut e = Engine::new(
            MockBackend::tiny(),
            EngineConfig { hbm_kv_bytes: 0, overlap, policy, ..Default::default() },
        );
        e.submit(vec![1, 2, 3, 4], 80);
        e.run_to_completion(400).unwrap();
        (
            e.take_responses().pop().unwrap().tokens,
            e.device.stats(),
            e.metrics.pages_spilled,
            e.metrics.prefetch_stale,
        )
    };
    let (st, ss, spilled, _) = run80(false);
    let (ot, os, _, stale) = run80(true);
    assert!(spilled >= 5, "need enough pages for a drop, got {spilled}");
    assert_eq!(st, ot, "tokens across a drop transition");
    assert_eq!(ss, os, "traffic across a drop transition");
    assert_eq!(stale, 0, "drops must be predicted, not fenced");
}

#[test]
fn overlap_never_slower_and_equal_without_spill() {
    // generous HBM: nothing spills, there is nothing to prefetch, and the
    // two pipelines take identical model time
    let run_hbm = |overlap: bool| -> (Vec<Vec<u32>>, u64, f64, u64) {
        let mut e = Engine::new(
            MockBackend::tiny(),
            EngineConfig { hbm_kv_bytes: 16 << 20, overlap, ..Default::default() },
        );
        e.submit(vec![1, 2, 3, 4], 40);
        e.run_to_completion(200).unwrap();
        let toks = e.take_responses().pop().unwrap().tokens;
        (vec![toks], e.metrics.pages_spilled, e.metrics.model_ns, e.metrics.prefetch_issued)
    };
    let (st, s_spill, s_ns, _) = run_hbm(false);
    let (ot, o_spill, o_ns, o_issued) = run_hbm(true);
    assert_eq!((s_spill, o_spill), (0, 0));
    assert_eq!(st, ot);
    assert_eq!(o_issued, 0, "nothing spilled, nothing to prefetch");
    assert!((s_ns - o_ns).abs() < 1e-6, "serial {s_ns} vs overlapped {o_ns}");
}

#[test]
fn stale_prefetch_fence_discards_promoted_page() {
    // Regression: a page promoted CXL→HBM *between* prefetch issue and
    // consumption must be discarded by the fence. With a reduced-precision
    // tier ladder the stale payload holds truncated values, so consuming
    // it would visibly corrupt the attention input (the mock decode reads
    // the cache) — tokens must instead match the serial engine subjected
    // to the identical promotion schedule.
    let policy = KvPolicy::DynamicQuant { bf16: 1, fp8: 1, fp4: 30 };
    let run = |overlap: bool| -> (Vec<u32>, u64, u64) {
        let mut e = Engine::new(
            MockBackend::tiny(),
            EngineConfig { hbm_kv_bytes: 0, overlap, policy, ..Default::default() },
        );
        e.submit(vec![1, 2, 3, 4], 50);
        // run until 3 pages spilled: page 0 has slid down the ladder to a
        // truncated (FP8) tier, so its in-flight prefetch payload differs
        // from the full-precision HBM copy — consuming it would corrupt
        for _ in 0..45 {
            e.step().unwrap();
        }
        assert!(e.metrics.pages_spilled >= 3, "need ≥3 spilled pages before promoting");
        // the overlap engine has already prefetched page 0 for step 46;
        // grow the (zero-byte) partition so the migration has headroom
        let pb = e.page_bytes();
        e.hbm.grow_usable(pb);
        assert!(e.promote_page_to_hbm(0, 0));
        e.run_to_completion(300).unwrap();
        let tokens = e.take_responses().pop().unwrap().tokens;
        (tokens, e.metrics.prefetch_stale, e.metrics.pages_promoted)
    };
    let (serial_tokens, serial_stale, sp) = run(false);
    let (overlap_tokens, overlap_stale, op) = run(true);
    assert_eq!((sp, op), (1, 1));
    assert_eq!(serial_stale, 0);
    assert!(overlap_stale >= 1, "promotion must invalidate the in-flight prefetch");
    assert_eq!(serial_tokens, overlap_tokens, "fence must keep tokens identical");
}

#[test]
fn overlapped_model_time_converges_to_compute_bound() {
    // with everything spilled and FullKv, the overlapped engine should
    // hide (nearly) the whole fetch under compute: its per-step model
    // time approaches compute_ns, while the serial engine pays the chain
    let run = |overlap: bool| -> (f64, u64) {
        let mut e = Engine::new(
            MockBackend::tiny(),
            EngineConfig { hbm_kv_bytes: 0, overlap, ..Default::default() },
        );
        e.submit(vec![1; 8], 64);
        e.run_to_completion(300).unwrap();
        (e.metrics.model_ns, e.metrics.engine_steps)
    };
    let (serial_ns, steps_s) = run(false);
    let (overlap_ns, steps_o) = run(true);
    assert_eq!(steps_s, steps_o, "same step count either way");
    let compute_floor = steps_s as f64 * EngineConfig::default().compute_ns;
    // overlapped: within 20% of pure compute; serial: clearly above it
    assert!(overlap_ns < compute_floor * 1.2, "overlap {overlap_ns} floor {compute_floor}");
    assert!(serial_ns > overlap_ns * 1.02, "serial {serial_ns} overlap {overlap_ns}");
}
