//! Failure-injection tests: corruption in the device's compressed planes
//! or metadata must surface as *errors*, never as silently wrong
//! host-visible data — the correctness invariant of paper §III-D demands
//! bit-exactness or a fault, nothing in between.

use trace_cxl::bitplane::{DeviceBlock, KvWindow, PlaneMask};
use trace_cxl::codec::{self, CodecKind, CodecPolicy};
use trace_cxl::formats::Fmt;
use trace_cxl::gen::KvGen;
use trace_cxl::util::check::props;
use trace_cxl::util::Rng;

#[test]
fn corrupt_compressed_plane_errors_or_differs_loudly() {
    // truncating any compressed plane stream must produce a decode error
    // (length mismatch), not plausible-but-wrong words
    let mut rng = Rng::new(901);
    let kv = KvGen::default_for(64).generate(&mut rng, 64);
    let blk = DeviceBlock::encode_kv(&kv, KvWindow::new(64, 64), CodecPolicy::AllBest);
    for plane in 0..16 {
        if blk.planes[plane].codec == CodecKind::Raw || blk.planes[plane].data.len() < 2 {
            continue;
        }
        let mut bad = blk.clone();
        let n = bad.planes[plane].data.len();
        bad.planes[plane].data.truncate(n - 1);
        assert!(
            bad.decode_full().is_err(),
            "plane {plane} truncation must fail decode"
        );
    }
}

#[test]
fn bitflips_in_compressed_streams_never_roundtrip_silently() {
    // a random bit flip in an LZ4 stream either errors or changes output —
    // it must never be silently absorbed into "the same" data with a
    // different meaning for masked reads
    props(902, 100, |r| {
        let data = trace_cxl::util::check::arb_bytes(r, 2048);
        if data.len() < 16 {
            return;
        }
        let enc = codec::compress(CodecKind::Lz4, &data);
        let mut bad = enc.clone();
        let pos = r.below(bad.len());
        bad[pos] ^= 1 << r.below(8);
        match codec::decompress(CodecKind::Lz4, &bad, data.len()) {
            Err(_) => {}                       // detected: fine
            Ok(out) => {
                // undetected by framing: the payload must differ (the flip
                // cannot be a no-op because LZ4 has no redundancy)
                if out == data {
                    // flipping bits in unused literal-run padding can be
                    // benign only if the stream still decodes identically;
                    // accept but ensure re-compression reproduces content
                    let again = codec::decompress(CodecKind::Lz4, &bad, data.len()).unwrap();
                    assert_eq!(again, data);
                }
            }
        }
    });
}

#[test]
fn wrong_window_shape_is_rejected_loudly() {
    let mut rng = Rng::new(903);
    let kv = KvGen::default_for(32).generate(&mut rng, 32);
    let result = std::panic::catch_unwind(|| {
        DeviceBlock::encode_kv(&kv, KvWindow::new(64, 32), CodecPolicy::FastBest)
    });
    assert!(result.is_err(), "shape mismatch must not be silently padded");
}

#[test]
fn masked_reads_never_fabricate_unfetched_planes() {
    // for every mask, bits outside the mask are exactly zero in the
    // reassembled (pre-inverse) words — the device cannot hallucinate
    // detail it did not fetch
    props(904, 50, |r| {
        let n = 8 * (1 + r.below(64));
        let words: Vec<u16> = (0..n).map(|_| r.next_u32() as u16).collect();
        let blk = DeviceBlock::encode_weights(&words, Fmt::Bf16, CodecPolicy::FastBest);
        let mask = PlaneMask((r.next_u32() & 0xffff) | 0x8000);
        let got = blk.decode_words(mask).unwrap();
        for (g, w) in got.iter().zip(words.iter()) {
            assert_eq!(*g, w & (mask.0 as u16), "unfetched planes must be zero");
        }
    });
}

#[test]
fn device_read_after_partial_overwrite_is_consistent() {
    // overwriting a block address replaces it atomically
    use trace_cxl::cxl::{CxlDevice, Design, MemDevice, Transaction};
    let mut rng = Rng::new(905);
    let mut dev = CxlDevice::new(Design::Trace, CodecPolicy::FastBest);
    let a = KvGen::default_for(32).generate(&mut rng, 32);
    let b = KvGen::default_for(32).generate(&mut rng, 32);
    let read = |dev: &mut CxlDevice| {
        dev.submit_one(Transaction::ReadFull { block_addr: 0x1000 })
            .unwrap()
            .into_words()
            .unwrap()
    };
    dev.submit_one(Transaction::WriteKv {
        block_addr: 0x1000,
        words: a.clone(),
        window: KvWindow::new(32, 32),
    })
    .unwrap();
    assert_eq!(read(&mut dev), a);
    dev.submit_one(Transaction::WriteKv {
        block_addr: 0x1000,
        words: b.clone(),
        window: KvWindow::new(32, 32),
    })
    .unwrap();
    assert_eq!(read(&mut dev), b);
}

#[test]
fn guarded_devices_detect_or_repair_across_the_full_matrix() {
    // the self-healing contract, full-stack: for every design × shard
    // count × codec-lane count × decode-cache setting, damaging a
    // guarded block and reading it back must either return bit-identical
    // data (repaired from checksums + parity) or an error — never
    // silently wrong data
    use trace_cxl::cxl::{
        CxlDevice, Design, FaultPlan, MemDevice, ShardedDevice, Transaction,
        DEFAULT_DECODE_CACHE_BLOCKS,
    };
    let mut rng = Rng::new(907);
    let kv = KvGen::default_for(32).generate(&mut rng, 32);
    let addrs = [0x0u64, 0x1000, 0x2000, 0x3000];
    for design in [Design::Plain, Design::GComp, Design::Trace] {
        for shards in [1usize, 4] {
            for lanes in [1usize, 4] {
                for cache in [0usize, DEFAULT_DECODE_CACHE_BLOCKS] {
                    let tag = format!("{design:?}/s{shards}/l{lanes}/c{cache}");
                    let mut dev: Box<dyn MemDevice> = if shards > 1 {
                        let mut d = ShardedDevice::new(shards, design, CodecPolicy::FastBest);
                        d.set_codec_lanes(lanes);
                        d.set_decode_cache(cache);
                        Box::new(d)
                    } else {
                        let mut d = CxlDevice::new(design, CodecPolicy::FastBest);
                        d.set_codec_lanes(lanes);
                        d.set_decode_cache(cache);
                        Box::new(d)
                    };
                    dev.set_fault_plan(FaultPlan::guarded(11));
                    for &a in &addrs {
                        dev.submit_one(Transaction::WriteKv {
                            block_addr: a,
                            words: kv.clone(),
                            window: KvWindow::new(32, 32),
                        })
                        .unwrap();
                    }
                    for &a in &addrs {
                        assert!(dev.corrupt_block(a), "{tag}: block {a:#x} not corruptible");
                        match dev.submit_one(Transaction::ReadFull { block_addr: a }) {
                            Ok(p) => assert_eq!(
                                p.into_words().unwrap(),
                                kv,
                                "{tag}: {a:#x} repaired read must be bit-identical"
                            ),
                            Err(_) => {} // loud detection: acceptable
                        }
                    }
                    let st = dev.stats();
                    assert!(
                        st.faults_detected >= addrs.len() as u64,
                        "{tag}: every damaged read must be detected (got {})",
                        st.faults_detected
                    );
                    assert_eq!(
                        st.faults_detected,
                        st.faults_repaired + st.faults_unrecoverable,
                        "{tag}: every detection must resolve to repair or a loud error"
                    );
                    // a killed (multi-stream loss) block fails loudly and
                    // stays failed until a rewrite heals it
                    assert!(dev.test_kill_block(addrs[0]), "{tag}: kill");
                    assert!(
                        dev.submit_one(Transaction::ReadFull { block_addr: addrs[0] }).is_err(),
                        "{tag}: dead block must error, not fabricate data"
                    );
                    dev.submit_one(Transaction::WriteKv {
                        block_addr: addrs[0],
                        words: kv.clone(),
                        window: KvWindow::new(32, 32),
                    })
                    .unwrap();
                    let healed = dev
                        .submit_one(Transaction::ReadFull { block_addr: addrs[0] })
                        .unwrap()
                        .into_words()
                        .unwrap();
                    assert_eq!(healed, kv, "{tag}: rewrite must heal the dead block");
                }
            }
        }
    }
}

#[test]
fn error_completions_occupy_the_controller_like_successes() {
    // an error completion must be scheduled on the same resource
    // timelines as a success: same reservation count, a real (nonzero)
    // ready-at time — failed transactions occupy the controller too
    use trace_cxl::cxl::{CxlDevice, Design, FaultPlan, MemDevice, SubmissionQueue, Transaction};
    let mut rng = Rng::new(908);
    let kv = KvGen::default_for(32).generate(&mut rng, 32);
    let build = || {
        let mut d = CxlDevice::new(Design::Trace, CodecPolicy::FastBest);
        d.install_fault_plan(FaultPlan::guarded(5));
        d.submit_one(Transaction::WriteKv {
            block_addr: 0x0,
            words: kv.clone(),
            window: KvWindow::new(32, 32),
        })
        .unwrap();
        d
    };
    // success path
    let mut ok_dev = build();
    let base_res = ok_dev.service_tl.reservations();
    let mut sq = SubmissionQueue::new();
    sq.submit(Transaction::ReadFull { block_addr: 0x0 });
    let ok = ok_dev.drain_at(&mut sq, 1000.0).pop().unwrap();
    assert!(ok.result.is_ok());
    // error path: same read, but the block is dead
    let mut err_dev = build();
    err_dev.test_kill_block(0x0);
    let mut sq = SubmissionQueue::new();
    sq.submit(Transaction::ReadFull { block_addr: 0x0 });
    let err = err_dev.drain_at(&mut sq, 1000.0).pop().unwrap();
    assert!(err.result.is_err());
    assert_eq!(
        err_dev.service_tl.reservations() - base_res,
        ok_dev.service_tl.reservations() - base_res,
        "error completions must reserve the controller timeline like successes"
    );
    assert!(err.issued_ns >= 1000.0, "error completion carries a real issue time");
    assert!(
        err.ready_at_ns > err.issued_ns,
        "error completion carries a timeline-derived ready-at time"
    );
    // both occupy the device for model time; the error still charges the
    // metadata + pipeline path even though no data moved
    assert!(err_dev.service_tl.busy_ns() > 0.0);
}

#[test]
fn failed_transactions_complete_as_errors_without_poisoning_the_batch() {
    // a missing block mid-batch must yield an error completion while the
    // rest of the submission drains normally — never a panic, never
    // silently wrong data
    use trace_cxl::cxl::{CxlDevice, Design, MemDevice, SubmissionQueue, Transaction};
    let mut rng = Rng::new(906);
    let kv = KvGen::default_for(32).generate(&mut rng, 32);
    let mut dev = CxlDevice::new(Design::Trace, CodecPolicy::FastBest);
    dev.submit_one(Transaction::WriteKv {
        block_addr: 0x0,
        words: kv.clone(),
        window: KvWindow::new(32, 32),
    })
    .unwrap();
    let mut sq = SubmissionQueue::new();
    let good_a = sq.submit(Transaction::ReadFull { block_addr: 0x0 });
    let missing = sq.submit(Transaction::ReadFull { block_addr: 0xdead0000 });
    let good_b = sq.submit(Transaction::ReadView {
        block_addr: 0x0,
        view: trace_cxl::bitplane::PrecisionView::bf16_mantissa(3, 1),
    });
    let completions = dev.drain(&mut sq);
    assert_eq!(completions.len(), 3);
    for c in completions {
        if c.id == missing {
            assert!(c.result.is_err());
        } else {
            assert!(c.result.is_ok(), "txn {} failed", c.id);
            assert!(c.id == good_a || c.id == good_b);
        }
    }
}
