//! Integration tests across runtime + coordinator + device.
//!
//! The PJRT-backed tests need the `pjrt` feature (XLA bindings are not in
//! the offline vendor set) *and* compiled artifacts: they look for
//! `TRACE_TEST_ARTIFACTS` (a directory produced by
//! `python -m compile.aot --test-dims`) or fall back to generating it via
//! the Python toolchain when available; otherwise those tests are skipped.
//! Mock-backend coverage always runs.

use trace_cxl::coordinator::{Engine, EngineConfig};
use trace_cxl::cxl::{Design, MemDevice};
use trace_cxl::runtime::MockBackend;

#[cfg(feature = "pjrt")]
mod pjrt_backed {
    use std::path::PathBuf;
    use std::process::Command;
    use std::sync::OnceLock;

    use trace_cxl::codec::CodecPolicy;
    use trace_cxl::coordinator::{Engine, EngineConfig};
    use trace_cxl::cxl::{Design, MemDevice};
    use trace_cxl::runtime::{ModelBackend, PjrtEngine};
    use trace_cxl::tier::KvPolicy;

    fn test_artifacts() -> Option<PathBuf> {
        static DIR: OnceLock<Option<PathBuf>> = OnceLock::new();
        DIR.get_or_init(|| {
            if let Ok(d) = std::env::var("TRACE_TEST_ARTIFACTS") {
                let p = PathBuf::from(d);
                if p.join("manifest.json").exists() {
                    return Some(p);
                }
            }
            // try to build tiny artifacts with the python toolchain
            let out = std::env::temp_dir().join("trace_cxl_test_artifacts");
            if out.join("manifest.json").exists() {
                return Some(out);
            }
            let py_dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).parent()?.join("python");
            let status = Command::new("python")
                .args(["-m", "compile.aot", "--out-dir"])
                .arg(&out)
                .arg("--test-dims")
                .env("TRACE_TRAIN_STEPS", "0")
                .current_dir(&py_dir)
                .status()
                .ok()?;
            if status.success() {
                Some(out)
            } else {
                None
            }
        })
        .clone()
    }

    #[test]
    fn pjrt_engine_prefill_decode_roundtrip() {
        let Some(dir) = test_artifacts() else {
            eprintln!("skipping: no python toolchain for test artifacts");
            return;
        };
        let mut eng = PjrtEngine::load(&dir).expect("load artifacts");
        let dims = eng.dims().clone();
        assert_eq!(dims.layers, 2);

        let prompts =
            vec![vec![1u32, 2, 3, 4, 5, 6, 7, 8], vec![9, 10, 11, 12, 13, 14, 15, 16]];
        let pre = eng.prefill(&prompts).unwrap();
        assert_eq!(pre.logits.len(), dims.batch);
        assert_eq!(pre.logits[0].len(), dims.vocab);
        assert_eq!(pre.kv[0].len(), dims.t_prompt * dims.kv_entry_len());
        assert!(pre.logits[0].iter().all(|x| x.is_finite()));

        let toks = vec![5u32, 6];
        let dec = eng.decode(&toks, &pre.kv, dims.t_prompt).unwrap();
        assert_eq!(dec.logits.len(), dims.batch);
        assert_eq!(dec.kv_new[0].len(), dims.kv_entry_len());
        assert!(dec.kv_new[0].iter().any(|&x| x != 0.0));

        // decode is deterministic
        let dec2 = eng.decode(&toks, &pre.kv, dims.t_prompt).unwrap();
        assert_eq!(dec.logits, dec2.logits);
    }

    #[test]
    fn pjrt_decode_depends_on_kv_history() {
        let Some(dir) = test_artifacts() else {
            return;
        };
        let mut eng = PjrtEngine::load(&dir).expect("load artifacts");
        let dims = eng.dims().clone();
        let prompts = vec![vec![1u32; dims.t_prompt], vec![2u32; dims.t_prompt]];
        let pre = eng.prefill(&prompts).unwrap();
        let dec_a = eng.decode(&[3, 3], &pre.kv, dims.t_prompt).unwrap();
        // perturb the KV history: logits must change
        let mut kv_b = pre.kv.clone();
        for x in kv_b[0].iter_mut().take(64) {
            *x += 1.0;
        }
        let dec_b = eng.decode(&[3, 3], &kv_b, dims.t_prompt).unwrap();
        assert_ne!(dec_a.logits[0], dec_b.logits[0], "attention must read the cache");
    }

    #[test]
    fn engine_e2e_on_pjrt_backend_with_spill() {
        let Some(dir) = test_artifacts() else {
            return;
        };
        let backend = PjrtEngine::load(&dir).expect("load artifacts");
        let mut engine = Engine::new(
            backend,
            EngineConfig {
                design: Design::Trace,
                codec: CodecPolicy::FastBest,
                hbm_kv_bytes: 0, // force every page to spill through the device
                policy: KvPolicy::FullKv,
                greedy: true,
                shards: 1,
                ..Default::default()
            },
        );
        engine.submit(vec![1, 2, 3, 4], 18);
        engine.submit(vec![5, 6, 7], 16);
        engine.run_to_completion(200).unwrap();
        let rs = engine.take_responses();
        assert_eq!(rs.len(), 2);
        assert!(engine.metrics.pages_spilled > 0, "must exercise the CXL path");
        assert!(engine.device.stats().dram_bytes_written > 0);
    }
}

#[test]
fn engine_lossless_spill_equivalence_mock() {
    // spilling through TRACE must not change generated tokens (mock backend,
    // always available)
    let run = |hbm: u64, design: Design| {
        let mut e = Engine::new(
            MockBackend::tiny(),
            EngineConfig { design, hbm_kv_bytes: hbm, ..Default::default() },
        );
        e.submit(vec![1, 2, 3], 40);
        e.run_to_completion(200).unwrap();
        e.take_responses().pop().unwrap().tokens
    };
    let reference = run(1 << 20, Design::Plain);
    for design in [Design::Plain, Design::GComp, Design::Trace] {
        assert_eq!(run(0, design), reference, "{design:?} spill changed tokens");
    }
}

#[test]
fn engine_lossless_spill_equivalence_sharded_mock() {
    // the same invariant with a 4-shard device fleet behind the engine
    let run = |shards: usize| {
        let mut e = Engine::new(
            MockBackend::tiny(),
            EngineConfig { hbm_kv_bytes: 0, shards, ..Default::default() },
        );
        e.submit(vec![1, 2, 3], 40);
        e.run_to_completion(200).unwrap();
        assert!(e.metrics.pages_spilled > 0);
        assert_eq!(e.device.shards(), shards);
        e.take_responses().pop().unwrap().tokens
    };
    assert_eq!(run(1), run(4));
}
