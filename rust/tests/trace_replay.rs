//! Trace capture → replay equivalence, plus decode robustness on
//! corrupted trace files (mirroring `codec_robustness.rs` for the trace
//! format's trust boundary).
//!
//! * Capture→replay must be **bit-identical**: rebuilding the engine
//!   from the trace header (`CaptureMeta`) and re-driving the recorded
//!   submissions yields the same tokens, the same device traffic, the
//!   same latency vectors — and therefore byte-identical trace files —
//!   across schedulers, serial/overlapped pipelines, and shard counts.
//! * Shared-prefix workloads (rag-fanout) replay identically too, with
//!   page sharing re-established from the recorded `PrefixShare`s.
//! * Truncation at *every* byte boundary, bit flips, and garbage must
//!   come back as `Err` (or a well-formed parse) — never a panic.
//! * Shedding at the poll-log cap leaves an `EventsDropped` marker in
//!   the log and the metrics, while the trace sink retains every event.

use trace_cxl::coordinator::{EngineEvent, SchedKind, SlaClass};
use trace_cxl::cxl::{DeviceStats, MemDevice};
use trace_cxl::gen::{scenarios, SynthCorpus};
use trace_cxl::runtime::{MockBackend, ModelDims};
use trace_cxl::trace::{diff, resubmit, CaptureMeta, Trace, TraceWriter};
use trace_cxl::util::Rng;

/// Everything observable about a finished run, f64s compared by bits.
#[derive(Debug, PartialEq)]
struct Fingerprint {
    tokens: Vec<(u64, Vec<u32>)>,
    stats: DeviceStats,
    model_ns: u64,
    ttft: Vec<u64>,
    tpot: Vec<u64>,
    pages_hbm: u64,
    pages_spilled: u64,
    pages_shared: u64,
    preemptions: u64,
    tokens_generated: u64,
}

fn fingerprint(e: &mut trace_cxl::coordinator::Engine<MockBackend>) -> Fingerprint {
    let mut rs = e.take_responses();
    rs.sort_by_key(|r| r.id);
    Fingerprint {
        tokens: rs.into_iter().map(|r| (r.id, r.tokens)).collect(),
        stats: e.device.stats(),
        model_ns: e.metrics.model_ns.to_bits(),
        ttft: e.metrics.ttft_model_ns.iter().map(|x| x.to_bits()).collect(),
        tpot: e.metrics.tpot_model_ns.iter().map(|x| x.to_bits()).collect(),
        pages_hbm: e.metrics.pages_hbm,
        pages_spilled: e.metrics.pages_spilled,
        pages_shared: e.metrics.pages_shared,
        preemptions: e.metrics.preemptions,
        tokens_generated: e.metrics.tokens_generated,
    }
}

/// A bursty mixed-QoS workload: overloads the tiny engine so Priority
/// runs preempt, and every request still finishes.
fn submit_workload(e: &mut trace_cxl::coordinator::Engine<MockBackend>, t_prompt: usize) {
    let mut corpus = SynthCorpus::new(64, 3);
    for i in 0..10u64 {
        let plen = 2 + (i as usize * 3) % t_prompt.max(3);
        let prompt = corpus.take(plen.min(t_prompt));
        let (sla, max_new) =
            if i % 3 == 0 { (SlaClass::Interactive, 6) } else { (SlaClass::Batch, 24) };
        // arrivals bunch up in two waves to force queueing
        let arrival = if i < 5 { i as f64 * 500.0 } else { 40_000.0 + i as f64 * 500.0 };
        e.submit_at(prompt, max_new, arrival, sla);
    }
}

/// Capture the workload under `meta`'s config; return the trace bytes
/// and the run fingerprint.
fn capture(meta: &CaptureMeta) -> (Vec<u8>, Fingerprint) {
    let mut e = meta.build_mock_engine().unwrap();
    e.set_trace_sink(TraceWriter::new(&meta.to_json()));
    submit_workload(&mut e, meta.dims.t_prompt);
    e.run_to_completion(100_000).unwrap();
    assert_eq!(e.metrics.requests_finished, 10, "capture run must finish");
    let bytes = e.take_trace_sink().unwrap().finish();
    (bytes, fingerprint(&mut e))
}

/// Replay a parsed trace into a fresh engine rebuilt from its header;
/// return the replayed trace bytes and fingerprint.
fn replay(trace: &Trace) -> (Vec<u8>, Fingerprint) {
    let meta = CaptureMeta::from_json(&trace.meta).unwrap();
    let mut e = meta.build_mock_engine().unwrap();
    e.set_trace_sink(TraceWriter::new(&trace.meta));
    let n = resubmit(&mut e, trace);
    assert_eq!(n, trace.submits().len());
    e.run_to_completion(100_000).unwrap();
    let bytes = e.take_trace_sink().unwrap().finish();
    (bytes, fingerprint(&mut e))
}

fn tiny_meta() -> CaptureMeta {
    let mut meta = CaptureMeta::mock(MockBackend::tiny().dims().clone(), 42);
    meta.hbm_kv_bytes = 4096; // ~2 pages: long decodes must spill
    meta
}

#[test]
fn replay_is_bit_identical_across_sched_overlap_shards() {
    for sched in [SchedKind::Fcfs, SchedKind::Priority] {
        for overlap in [false, true] {
            for shards in [1usize, 4] {
                let tag = format!("{} overlap={overlap} shards={shards}", sched.name());
                let mut meta = tiny_meta();
                meta.sched = sched;
                meta.overlap = overlap;
                meta.shards = shards;

                let (bytes, fp) = capture(&meta);
                let trace = Trace::parse(&bytes).unwrap();
                assert_eq!(trace.submits().len(), 10, "{tag}");
                if sched == SchedKind::Priority {
                    assert!(fp.preemptions > 0, "{tag}: overload must preempt");
                }

                let (bytes2, fp2) = replay(&trace);
                assert_eq!(fp, fp2, "{tag}: replay fingerprint diverged");
                assert_eq!(bytes, bytes2, "{tag}: trace files must be byte-identical");
                let d = diff(&trace, &Trace::parse(&bytes2).unwrap());
                assert!(d.is_empty(), "{tag}: {}", d.report());
            }
        }
    }
}

#[test]
fn shared_prefix_workload_replays_identically() {
    let dims = ModelDims {
        layers: 2,
        batch: 4,
        t_max: 256,
        t_prompt: 112,
        d_model: 16,
        heads: 2,
        head_dim: 4,
        ffn: 32,
        vocab: 64,
    };
    let mut meta = CaptureMeta::mock(dims.clone(), 42);
    meta.hbm_kv_bytes = 0; // every page (shared or not) lives on the device
    meta.scenario = Some("rag-fanout".to_string());
    meta.gen_seed = 5;

    let sc = scenarios::by_name("rag-fanout").unwrap();
    let mut e = meta.build_mock_engine().unwrap();
    e.set_trace_sink(TraceWriter::new(&meta.to_json()));
    for r in sc.generate(5, 12, dims.vocab as u32, dims.t_prompt, 8) {
        match r.prefix {
            Some(p) => e.submit_shared_at(r.prompt, r.max_new, r.arrival_ns, r.sla, p),
            None => e.submit_at(r.prompt, r.max_new, r.arrival_ns, r.sla),
        };
    }
    e.run_to_completion(100_000).unwrap();
    assert_eq!(e.metrics.requests_finished, 12);
    assert!(e.metrics.pages_shared > 0, "rag-fanout must attach to shared pages");
    assert_eq!(e.device.len(), 0, "refcounted shared pages must free exactly once");
    let bytes = e.take_trace_sink().unwrap().finish();
    let fp = fingerprint(&mut e);

    let trace = Trace::parse(&bytes).unwrap();
    let shared_submits = trace.submits().iter().filter(|s| s.prefix.is_some()).count();
    assert_eq!(shared_submits, 12, "every rag submission records its PrefixShare");

    let (bytes2, fp2) = replay(&trace);
    assert_eq!(fp, fp2, "shared-prefix replay diverged");
    assert_eq!(bytes, bytes2);
    assert_eq!(fp2.pages_shared, fp.pages_shared);
}

#[test]
fn nmc_capture_replays_bit_identically_and_records_offloads() {
    let mut meta = tiny_meta();
    meta.hbm_kv_bytes = 0; // every page spills: the offload path is hot
    meta.shards = 4;
    meta.nmc = true;

    let (bytes, fp) = capture(&meta);
    let trace = Trace::parse(&bytes).unwrap();
    assert_eq!(trace.version, 3);
    let parsed = CaptureMeta::from_json(&trace.meta).unwrap();
    assert!(parsed.nmc, "nmc flag must survive the meta header");
    let (offloads, scanned, saved) = trace.nmc_totals();
    assert!(offloads > 0 && scanned > 0 && saved > 0, "capture must record NMC activity");

    let (bytes2, fp2) = replay(&trace);
    assert_eq!(fp, fp2, "nmc replay fingerprint diverged");
    assert_eq!(bytes, bytes2, "nmc trace files must be byte-identical");
    assert_eq!(Trace::parse(&bytes2).unwrap().nmc_totals(), (offloads, scanned, saved));
}

#[test]
fn v1_stream_with_nmc_opcode_is_a_decode_error() {
    let mut meta = tiny_meta();
    meta.hbm_kv_bytes = 0;
    meta.shards = 4;
    meta.nmc = true;
    let (mut bytes, _) = capture(&meta);
    assert!(Trace::parse(&bytes).is_ok());
    // relabel the stream as v1: the OP_NMC records it carries are not
    // part of the v1 grammar and must fail decode, not silently skip
    bytes[4] = 1;
    let err = Trace::parse(&bytes).unwrap_err();
    assert!(err.to_string().contains("not valid in a version 1"), "{err}");
}

#[test]
fn truncation_at_every_byte_is_a_decode_error() {
    let (bytes, _) = capture(&tiny_meta());
    assert!(Trace::parse(&bytes).is_ok());
    // every prefix of a real capture must fail to parse — the end record
    // makes "trace ended early" indistinguishable from corruption
    let cuts: Vec<usize> = if bytes.len() <= 4096 {
        (0..bytes.len()).collect()
    } else {
        let mut r = Rng::new(0xC0FFEE);
        let mut v: Vec<usize> = (0..64).map(|_| r.below(bytes.len())).collect();
        v.extend(0..512); // always cover the header densely
        v.push(bytes.len() - 1);
        v
    };
    for cut in cuts {
        assert!(Trace::parse(&bytes[..cut]).is_err(), "cut at {cut} must not parse");
    }
}

#[test]
fn bitflips_and_garbage_never_panic() {
    let (mut bytes, _) = capture(&tiny_meta());
    let mut r = Rng::new(0xF1A6);
    for _ in 0..400 {
        let i = r.below(bytes.len());
        let bit = 1u8 << r.below(8);
        bytes[i] ^= bit;
        let _ = Trace::parse(&bytes); // Err or a well-formed parse; no panic
        bytes[i] ^= bit; // restore
    }
    assert!(Trace::parse(&bytes).is_ok(), "restore must round-trip");

    // pure garbage: wrong magic is an immediate error
    let mut garbage = vec![0u8; 512];
    r.fill_bytes(&mut garbage);
    garbage[..4].copy_from_slice(b"NOPE");
    assert!(Trace::parse(&garbage).is_err());
    // right magic, garbage body: still an error, still no panic
    garbage[..4].copy_from_slice(b"TRCX");
    assert!(Trace::parse(&garbage).is_err());
    assert!(Trace::parse(&[]).is_err());
}

#[test]
fn poll_log_shedding_leaves_markers_but_the_sink_keeps_everything() {
    let meta = tiny_meta();
    let mut e = meta.build_mock_engine().unwrap();
    e.set_trace_sink(TraceWriter::new(&meta.to_json()));
    e.set_event_log_cap(8); // force shedding with a small workload
    submit_workload(&mut e, meta.dims.t_prompt);
    e.run_to_completion(100_000).unwrap();

    assert!(e.metrics.events_dropped > 0, "tiny cap must shed");
    let events = e.poll_events();
    assert!(events.len() <= 8 + 1, "log stays near its cap");
    let dropped_in_log: u64 = events
        .iter()
        .filter_map(|ev| match ev {
            EngineEvent::EventsDropped { count, .. } => Some(*count),
            _ => None,
        })
        .sum();
    assert!(dropped_in_log > 0, "the log must carry an EventsDropped marker");

    // metrics surface the same counter at the top level of the JSON dump
    let json = e.metrics.to_json(&e.device.stats()).to_string();
    assert!(json.contains("\"events_dropped\""), "{json}");

    // the sink saw every token even though the poll log shed most of them
    let trace = Trace::parse(&e.take_trace_sink().unwrap().finish()).unwrap();
    let trace_tokens: usize = trace.tokens_by_seq().values().map(Vec::len).sum();
    assert_eq!(trace_tokens as u64, e.metrics.tokens_generated);
    assert!(trace.events_dropped() > 0, "shed markers are recorded in the trace too");
}
