//! Corrupted-stream robustness: truncated, bit-flipped, and pure-garbage
//! codec streams must come back as `Err` (or, where the corruption happens
//! to decode, as a well-formed buffer of exactly the expected length) —
//! never a panic, never an out-of-bounds read — through both the
//! allocating `decompress` and the scratch-path `decompress_into`.
//!
//! The device serves attacker-shaped bytes only from its own writes, but
//! plane streams cross the (simulated) DRAM and metadata may desync; the
//! decode path is the trust boundary, so it gets fuzz-style coverage.
//!
//! PR-7 adds the *differential* layer: the vectorized decode kernels (SWAR
//! RLE, wild-copy LZ4, table-driven Huffman) are pinned byte-for-byte —
//! and Ok/Err-for-Ok/Err on corrupt input — against the scalar
//! predecessors they replaced, which stay in-tree as `*_scalar`
//! references. Every corpus shape above runs through both.

use trace_cxl::codec::{self, CodecKind, CodecPolicy};
use trace_cxl::util::check::{arb_bytes, props};
use trace_cxl::util::Rng;

const KINDS: [CodecKind; 4] =
    [CodecKind::Raw, CodecKind::Rle, CodecKind::Lz4, CodecKind::Zstd];

/// The vectorized decoder and its scalar predecessor must agree exactly:
/// same Ok/Err classification on any byte stream (valid or corrupt), and
/// identical output bytes on Ok. `Raw` has no vector/scalar split.
fn assert_vector_matches_scalar(kind: CodecKind, stream: &[u8], n: usize) {
    let mut v = vec![0xAAu8; n];
    let mut s = vec![0x55u8; n];
    let (rv, rs) = match kind {
        CodecKind::Raw => return,
        CodecKind::Rle => (
            codec::rle::decompress_into(stream, &mut v).is_ok(),
            codec::rle::decompress_into_scalar(stream, &mut s).is_ok(),
        ),
        CodecKind::Lz4 => (
            codec::lz4::decompress_into(stream, &mut v).is_ok(),
            codec::lz4::decompress_into_scalar(stream, &mut s).is_ok(),
        ),
        CodecKind::Zstd => {
            // the bulk API reports bytes written (it may succeed with
            // fewer than `n`), so compare counts + the written prefix
            let rv = zstd::bulk::decompress_to_buffer(stream, &mut v);
            let rs = zstd::bulk::decompress_to_buffer_scalar(stream, &mut s);
            assert_eq!(
                rv.is_ok(),
                rs.is_ok(),
                "Zstd: table/bit-loop Ok-Err classification diverged (n={n})"
            );
            if let (Ok(wv), Ok(ws)) = (rv, rs) {
                assert_eq!(wv, ws, "Zstd: written counts diverged (n={n})");
                assert_eq!(v[..wv], s[..ws], "Zstd: payload diverged (n={n})");
            }
            return;
        }
    };
    assert_eq!(rv, rs, "{kind:?}: vector/scalar Ok-Err classification diverged (n={n})");
    if rv {
        assert_eq!(v, s, "{kind:?}: vector/scalar payload diverged (n={n})");
    }
}

/// Decode must either error or produce exactly `n` bytes; both entry
/// points must agree on success/failure and on successful payloads.
fn assert_decode_well_behaved(kind: CodecKind, stream: &[u8], n: usize) {
    let alloc = codec::decompress(kind, stream, n);
    let mut buf = vec![0u8; n];
    let into = codec::decompress_into(kind, stream, &mut buf);
    match (&alloc, &into) {
        (Ok(v), Ok(())) => {
            assert_eq!(v.len(), n, "{kind:?}: wrong decode length");
            assert_eq!(v[..], buf[..], "{kind:?}: entry points disagree");
        }
        (Err(_), Err(_)) => {}
        _ => panic!(
            "{kind:?}: decompress ({}) and decompress_into ({}) disagree",
            if alloc.is_ok() { "ok" } else { "err" },
            if into.is_ok() { "ok" } else { "err" },
        ),
    }
    // and the vectorized kernel must track its scalar reference on the
    // same (possibly corrupt) stream — this threads the entire fuzz
    // corpus (truncations, bitflips, garbage) through the differential
    assert_vector_matches_scalar(kind, stream, n);
}

#[test]
fn truncated_streams_error_never_panic() {
    props(0xAB1, 150, |r| {
        let data = arb_bytes(r, 2048);
        for kind in KINDS {
            let enc = codec::compress(kind, &data);
            // every truncation point, for small streams; sampled for large
            let cuts: Vec<usize> = if enc.len() <= 64 {
                (0..enc.len()).collect()
            } else {
                (0..64).map(|_| r.below(enc.len())).collect()
            };
            for cut in cuts {
                assert_decode_well_behaved(kind, &enc[..cut], data.len());
            }
        }
    });
}

#[test]
fn bitflipped_streams_never_panic_or_overrun() {
    props(0xAB2, 150, |r| {
        let data = arb_bytes(r, 2048);
        for kind in KINDS {
            let mut enc = codec::compress(kind, &data);
            if enc.is_empty() {
                continue;
            }
            for _ in 0..8 {
                let at = r.below(enc.len());
                let bit = 1u8 << r.below(8);
                enc[at] ^= bit;
                assert_decode_well_behaved(kind, &enc, data.len());
                enc[at] ^= bit; // restore for the next flip
            }
        }
    });
}

#[test]
fn pure_garbage_never_panics() {
    props(0xAB3, 200, |r| {
        let garbage = arb_bytes(r, 512);
        let n = r.below(2049);
        for kind in KINDS {
            assert_decode_well_behaved(kind, &garbage, n);
        }
    });
}

#[test]
fn wrong_expected_length_errors() {
    props(0xAB4, 100, |r| {
        let data = arb_bytes(r, 1024);
        if data.is_empty() {
            return;
        }
        for kind in KINDS {
            let enc = codec::compress(kind, &data);
            // shorter and longer than the true decoded size must error
            // (never a silent truncation or over-read)
            assert!(codec::decompress(kind, &enc, data.len() - 1).is_err(), "{kind:?} short");
            assert!(codec::decompress(kind, &enc, data.len() + 1).is_err(), "{kind:?} long");
            let mut short = vec![0u8; data.len() - 1];
            assert!(codec::decompress_into(kind, &enc, &mut short).is_err(), "{kind:?}");
            let mut long = vec![0u8; data.len() + 1];
            assert!(codec::decompress_into(kind, &enc, &mut long).is_err(), "{kind:?}");
        }
    });
}

#[test]
fn vector_kernels_match_scalar_on_valid_streams() {
    // random corpus shapes (incompressible, runs, periodic, text, sparse)
    props(0xAB6, 120, |r| {
        let data = arb_bytes(r, 4096);
        for kind in [CodecKind::Rle, CodecKind::Lz4, CodecKind::Zstd] {
            let enc = codec::compress(kind, &data);
            assert_vector_matches_scalar(kind, &enc, data.len());
        }
    });
    // run-heavy planes with every tail residue mod 8 — the wild-copy
    // kernels' boundary cases (the safe-tail switchover)
    for tail in 0..8usize {
        let n = 4096 + tail;
        let mut runs = vec![0u8; n];
        let mut r = Rng::new(0xAB7 + tail as u64);
        let mut i = 0;
        while i < n {
            let run = 1 + r.below(24.min(n - i));
            let b = r.next_u32() as u8;
            for x in &mut runs[i..i + run] {
                *x = b;
            }
            i += run;
        }
        for kind in [CodecKind::Rle, CodecKind::Lz4, CodecKind::Zstd] {
            let enc = codec::compress(kind, &runs);
            assert_vector_matches_scalar(kind, &enc, n);
        }
    }
}

#[test]
fn corrupted_plane_stream_surfaces_as_device_error() {
    // end-to-end: a block whose compressed plane stream is corrupted mid
    // flight must complete as Err through the transaction API (serial,
    // pooled, and cached paths), not kill the process
    use trace_cxl::bitplane::KvWindow;
    use trace_cxl::cxl::{CxlDevice, Design, MemDevice, SubmissionQueue, Transaction};
    use trace_cxl::util::check::smooth_kv;

    let mut r = Rng::new(0xAB5);
    let kv = smooth_kv(&mut r, 32, 64);
    for (pool, cache) in [(1usize, 0usize), (4, 64)] {
        let mut d = CxlDevice::new(Design::Trace, CodecPolicy::AllBest);
        d.set_pool(pool);
        d.set_decode_cache(cache);
        d.submit_one(Transaction::WriteKv {
            block_addr: 0x0,
            words: kv.clone(),
            window: KvWindow::new(32, 64),
        })
        .unwrap();
        // corrupt the largest compressed plane stream in place
        assert!(d.test_corrupt_block(0x0), "block 0x0 must exist with a corruptible stream");
        let mut sq = SubmissionQueue::new();
        sq.submit(Transaction::ReadFull { block_addr: 0x0 });
        sq.submit(Transaction::ReadFull { block_addr: 0x0 });
        let cs = d.drain_at(&mut sq, 0.0);
        assert_eq!(cs.len(), 2);
        for c in cs {
            assert!(c.result.is_err(), "pool={pool} cache={cache}: corrupt stream must err");
        }
    }
}
