//! Chaos equivalence matrix: the fault-injection substrate must be
//! invisible when disabled, lossless under a repairable fault storm, and
//! deterministic per seed — and the engine's recovery ladder (failover →
//! requeue → degraded serving) must keep requests finishing when blocks
//! die for real.
//!
//! The gate from the issue: with `FaultPlan` off the engine is
//! bit-identical to a no-faults build across designs × shards ×
//! pipelines; under a repairable storm every request finishes with
//! bit-identical tokens and `failed == 0`.

use trace_cxl::coordinator::{Engine, EngineConfig, EngineEvent};
use trace_cxl::cxl::{Design, DeviceStats, FaultPlan, MemDevice};
use trace_cxl::runtime::MockBackend;

struct RunOut {
    tokens: Vec<Vec<u32>>,
    stats: DeviceStats,
    model_ns: f64,
    degraded: u64,
    failovers: u64,
}

fn run(design: Design, shards: usize, overlap: bool, faults: Option<FaultPlan>) -> RunOut {
    let mut e = Engine::new(
        MockBackend::tiny(),
        EngineConfig { design, hbm_kv_bytes: 0, shards, overlap, faults, ..Default::default() },
    );
    e.submit(vec![1, 2, 3, 4], 60);
    e.submit(vec![5, 6], 60);
    e.run_to_completion(300).unwrap();
    let mut rs = e.take_responses();
    assert_eq!(rs.len(), 2, "every request must finish");
    rs.sort_by_key(|r| r.id);
    RunOut {
        tokens: rs.into_iter().map(|r| r.tokens).collect(),
        stats: e.device.stats(),
        model_ns: e.metrics.model_ns,
        degraded: e.metrics.requests_degraded,
        failovers: e.metrics.fault_failovers,
    }
}

#[test]
fn disabled_plan_is_bit_identical_to_no_plan() {
    // FaultPlan off → the whole substrate vanishes: tokens, every stats
    // counter, and model time are bit-identical to an engine with no plan
    for design in [Design::Plain, Design::GComp, Design::Trace] {
        for shards in [1usize, 4] {
            for overlap in [false, true] {
                let tag = format!("{design:?} shards={shards} overlap={overlap}");
                let off = run(design, shards, overlap, None);
                let dis = run(design, shards, overlap, Some(FaultPlan::disabled(7)));
                assert_eq!(off.tokens, dis.tokens, "{tag}: tokens");
                assert_eq!(off.stats, dis.stats, "{tag}: device stats");
                assert_eq!(
                    off.model_ns.to_bits(),
                    dis.model_ns.to_bits(),
                    "{tag}: model time must be bit-identical"
                );
            }
        }
    }
}

#[test]
fn guards_cost_dram_but_never_change_tokens_or_link_traffic() {
    // zero-rate guarded plan: checksums + parity are stored and verified,
    // which shows up as extra DRAM traffic — but the host-visible stream
    // (tokens, link bytes) is untouched
    for shards in [1usize, 4] {
        let off = run(Design::Trace, shards, false, None);
        let g = run(Design::Trace, shards, false, Some(FaultPlan::guarded(7)));
        let tag = format!("shards={shards}");
        assert_eq!(off.tokens, g.tokens, "{tag}: tokens");
        assert_eq!(off.stats.link_bytes_out, g.stats.link_bytes_out, "{tag}: link out");
        assert_eq!(off.stats.link_bytes_in, g.stats.link_bytes_in, "{tag}: link in");
        assert!(
            g.stats.dram_bytes_written > off.stats.dram_bytes_written,
            "{tag}: guard storage must be charged"
        );
        assert!(
            g.stats.dram_bytes_read > off.stats.dram_bytes_read,
            "{tag}: guard verification must be charged"
        );
        assert_eq!(g.stats.faults_detected, 0, "{tag}: nothing to detect");
    }
}

#[test]
fn repairable_fault_storm_is_lossless() {
    // the issue's gate: under a chaos plan whose every fault is
    // repairable (guards on, retries on), all requests finish, tokens are
    // bit-identical to the fault-free run, and nothing fails terminally
    let mut total_repaired = 0;
    for seed in [3u64, 11, 42] {
        for shards in [1usize, 4] {
            let tag = format!("seed={seed} shards={shards}");
            let clean = run(Design::Trace, shards, false, None);
            let storm = run(Design::Trace, shards, false, Some(FaultPlan::chaos(seed)));
            assert_eq!(clean.tokens, storm.tokens, "{tag}: tokens must survive the storm");
            assert_eq!(storm.stats.faults_unrecoverable, 0, "{tag}: failed == 0");
            assert_eq!(storm.degraded, 0, "{tag}: no degraded requests");
            assert_eq!(storm.failovers, 0, "{tag}: device retries absorb everything");
            assert_eq!(
                storm.stats.faults_detected, storm.stats.faults_repaired,
                "{tag}: every detected corruption must be repaired"
            );
            assert!(
                storm.model_ns >= clean.model_ns,
                "{tag}: injected delay cannot make the run faster"
            );
            total_repaired += storm.stats.faults_repaired + storm.stats.faults_injected;
        }
    }
    assert!(total_repaired > 0, "the storm must actually inject faults somewhere");
}

#[test]
fn chaos_runs_are_deterministic_per_seed() {
    let a = run(Design::Trace, 4, true, Some(FaultPlan::chaos(42)));
    let b = run(Design::Trace, 4, true, Some(FaultPlan::chaos(42)));
    assert_eq!(a.tokens, b.tokens);
    assert_eq!(a.stats, b.stats);
    assert_eq!(a.model_ns.to_bits(), b.model_ns.to_bits());
    // a different seed lands faults elsewhere: the injected count or the
    // retry-delay total differs (tokens still must not)
    let c = run(Design::Trace, 4, true, Some(FaultPlan::chaos(43)));
    assert_eq!(a.tokens, c.tokens, "tokens are seed-independent");
}

#[test]
fn killed_block_fails_over_without_changing_tokens() {
    // rung 2 of the ladder: a spilled block dies on the device; the
    // demand fetch errors; the engine re-issues the spill write from the
    // authoritative host copy and the step completes — tokens identical
    // to a run where the block never died
    for overlap in [false, true] {
        let drive = |kill: bool| {
            let mut e = Engine::new(
                MockBackend::tiny(),
                EngineConfig {
                    hbm_kv_bytes: 0,
                    overlap,
                    faults: Some(FaultPlan::guarded(5)),
                    ..Default::default()
                },
            );
            e.submit(vec![1, 2, 3, 4], 60);
            for _ in 0..20 {
                e.step().unwrap();
            }
            assert!(e.metrics.pages_spilled > 0, "workload must spill");
            if kill {
                let addr = e
                    .pager
                    .pages
                    .iter()
                    .find_map(|p| p.cxl_addr)
                    .expect("a spilled page has a device address");
                assert!(e.device.test_kill_block(addr), "block must exist to kill");
            }
            e.run_to_completion(300).unwrap();
            (e.take_responses().pop().unwrap().tokens, e.metrics.fault_failovers)
        };
        let (clean_tokens, clean_failovers) = drive(false);
        let (tokens, failovers) = drive(true);
        let tag = format!("overlap={overlap}");
        assert_eq!(clean_failovers, 0, "{tag}");
        assert!(failovers > 0, "{tag}: the dead block must trigger a failover");
        assert_eq!(clean_tokens, tokens, "{tag}: failover must be invisible in tokens");
    }
}

#[test]
fn persistently_dead_block_degrades_instead_of_wedging() {
    // rung 4: a block that dies again after every failover exhausts the
    // failover budget; the page is served degraded (reduced precision)
    // from the host copy, the request is flagged, and the run finishes
    let mut e = Engine::new(
        MockBackend::tiny(),
        EngineConfig {
            hbm_kv_bytes: 0,
            faults: Some(FaultPlan::guarded(5)),
            ..Default::default()
        },
    );
    e.submit(vec![1, 2, 3, 4], 60);
    for _ in 0..20 {
        e.step().unwrap();
    }
    assert!(e.metrics.pages_spilled > 0);
    let addr =
        e.pager.pages.iter().find_map(|p| p.cxl_addr).expect("a spilled page has an address");
    // re-kill the block before every step until the engine gives up on it
    let mut guard = 0;
    while e.metrics.pages_degraded == 0 {
        e.device.test_kill_block(addr);
        e.step().unwrap();
        guard += 1;
        assert!(guard < 50, "degrade must trigger within the failover budget");
    }
    assert!(e.metrics.fault_failovers > 0, "failovers precede the degrade");
    let degraded_events = e
        .poll_events()
        .iter()
        .filter(|ev| matches!(ev, EngineEvent::Degraded { .. }))
        .count();
    assert!(degraded_events > 0, "the degrade must be observable");
    e.run_to_completion(300).unwrap();
    let r = e.take_responses().pop().expect("request finishes degraded, not wedged");
    assert!(!r.tokens.is_empty());
    assert!(e.metrics.requests_degraded >= 1);
    assert!(e.metrics.pages_degraded >= 1);
}

#[test]
fn chaos_capture_replays_bit_identically() {
    // the issue's trace gate: capture a chaos run, replay it from the
    // trace header (the fault plan rides in the meta), and the traces
    // diff clean — including the fault records themselves
    use trace_cxl::trace::{diff, resubmit, CaptureMeta, Trace, TraceWriter};
    let mut meta = CaptureMeta::mock(MockBackend::tiny().dims().clone(), 42);
    meta.hbm_kv_bytes = 0;
    meta.shards = 2;
    meta.faults = Some(FaultPlan::chaos(9));
    let mut e = meta.build_mock_engine().unwrap();
    e.set_trace_sink(TraceWriter::new(&meta.to_json()));
    e.submit(vec![1, 2, 3, 4], 40);
    e.submit(vec![5, 6], 40);
    e.run_to_completion(300).unwrap();
    let bytes = e.take_trace_sink().unwrap().finish();

    let trace = Trace::parse(&bytes).unwrap();
    assert_eq!(trace.version, 3);
    let totals = trace.fault_totals();
    assert!(totals.injected > 0, "the chaos capture must record fault activity");

    let parsed = CaptureMeta::from_json(&trace.meta).unwrap();
    assert_eq!(parsed.faults, meta.faults, "the plan must survive the header");
    let mut re = parsed.build_mock_engine().unwrap();
    re.set_trace_sink(TraceWriter::new(&trace.meta));
    let n = resubmit(&mut re, &trace);
    assert_eq!(n, trace.submits().len());
    re.run_to_completion(300).unwrap();
    let replay_bytes = re.take_trace_sink().unwrap().finish();
    assert_eq!(bytes, replay_bytes, "chaos capture must replay byte-for-byte");
    let replay = Trace::parse(&replay_bytes).unwrap();
    let d = diff(&trace, &replay);
    assert!(d.is_empty(), "chaos replay diverged:\n{}", d.report());
    assert_eq!(totals, replay.fault_totals());
}
