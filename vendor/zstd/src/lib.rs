//! Minimal offline stand-in for the `zstd` crate's `bulk` API.
//!
//! The workspace builds against a fixed vendor set with no registry access,
//! so this crate supplies `zstd::bulk::{compress, decompress}` with the
//! same signatures the real crate exposes. It is **not** the zstd wire
//! format: payloads are coded with a canonical-Huffman entropy coder plus a
//! raw bypass. That preserves the property the TRACE model actually relies
//! on — an "amortizable, stronger-than-LZ4 on low-entropy streams" codec —
//! while staying a few hundred lines of dependency-free Rust.
//!
//! Framing: `[mode u8]` then either the raw payload (mode 0) or, for mode 1,
//! `varint n` (decoded length), `K-1 u8` (distinct symbols), `K` pairs of
//! `[symbol u8][code_len u8]` sorted by `(len, symbol)`, and the MSB-first
//! bitstream. Corrupt or truncated input yields `io::Error`, never a panic.

pub mod bulk {
    use std::io;

    const MODE_RAW: u8 = 0;
    const MODE_HUFF: u8 = 1;
    /// Depth cap keeps canonical codes inside a u64; unreachable for real
    /// inputs below multi-terabyte sizes (Huffman depth grows ~log_phi(n)).
    const MAX_CODE_LEN: u32 = 48;

    fn bad(msg: &str) -> io::Error {
        io::Error::new(io::ErrorKind::InvalidData, msg)
    }

    fn put_varint(out: &mut Vec<u8>, mut v: u64) {
        loop {
            let b = (v & 0x7f) as u8;
            v >>= 7;
            if v == 0 {
                out.push(b);
                break;
            }
            out.push(b | 0x80);
        }
    }

    fn get_varint(b: &[u8]) -> Option<(u64, usize)> {
        let mut v: u64 = 0;
        let mut shift = 0u32;
        for (i, &byte) in b.iter().enumerate() {
            if shift >= 64 {
                return None;
            }
            v |= ((byte & 0x7f) as u64) << shift;
            if byte & 0x80 == 0 {
                return Some((v, i + 1));
            }
            shift += 7;
        }
        None
    }

    /// Compress `src`. `level` is accepted for API compatibility and
    /// ignored (there is a single operating point).
    pub fn compress(src: &[u8], _level: i32) -> io::Result<Vec<u8>> {
        if let Some(h) = huff_compress(src) {
            if h.len() < src.len() + 1 {
                return Ok(h);
            }
        }
        let mut out = Vec::with_capacity(src.len() + 1);
        out.push(MODE_RAW);
        out.extend_from_slice(src);
        Ok(out)
    }

    /// Decompress into at most `capacity` bytes.
    pub fn decompress(src: &[u8], capacity: usize) -> io::Result<Vec<u8>> {
        let (&mode, rest) = src.split_first().ok_or_else(|| bad("empty stream"))?;
        match mode {
            MODE_RAW => {
                if rest.len() > capacity {
                    return Err(bad("raw payload exceeds capacity"));
                }
                Ok(rest.to_vec())
            }
            MODE_HUFF => huff_decompress(rest, capacity),
            _ => Err(bad("bad mode byte")),
        }
    }

    /// Decompress into a caller-provided buffer (the real crate's
    /// `bulk::decompress_to_buffer` shape): writes the decoded payload to
    /// the front of `dst` and returns the number of bytes written, erroring
    /// if the payload would exceed `dst.len()`. Performs no allocation.
    pub fn decompress_to_buffer(src: &[u8], dst: &mut [u8]) -> io::Result<usize> {
        let (&mode, rest) = src.split_first().ok_or_else(|| bad("empty stream"))?;
        match mode {
            MODE_RAW => {
                if rest.len() > dst.len() {
                    return Err(bad("raw payload exceeds capacity"));
                }
                dst[..rest.len()].copy_from_slice(rest);
                Ok(rest.len())
            }
            MODE_HUFF => huff_decompress_into(rest, dst),
            _ => Err(bad("bad mode byte")),
        }
    }

    /// Huffman code lengths per symbol, or None when the input is empty or
    /// pathologically deep (caller falls back to the raw mode).
    fn code_lengths(freq: &[u64; 256]) -> Option<Vec<u32>> {
        let used: Vec<usize> = (0..256).filter(|&s| freq[s] > 0).collect();
        if used.is_empty() {
            return None;
        }
        let mut lens = vec![0u32; 256];
        if used.len() == 1 {
            lens[used[0]] = 1;
            return Some(lens);
        }
        // Plain two-queue-free heap construction with parent links.
        use std::cmp::Reverse;
        use std::collections::BinaryHeap;
        let n = used.len();
        let mut weight: Vec<u64> = used.iter().map(|&s| freq[s]).collect();
        let mut parent: Vec<usize> = vec![usize::MAX; n];
        let mut heap: BinaryHeap<Reverse<(u64, usize)>> =
            (0..n).map(|i| Reverse((weight[i], i))).collect();
        while heap.len() > 1 {
            let Reverse((wa, a)) = heap.pop().unwrap();
            let Reverse((wb, b)) = heap.pop().unwrap();
            let p = weight.len();
            weight.push(wa + wb);
            parent.push(usize::MAX);
            parent[a] = p;
            parent[b] = p;
            heap.push(Reverse((wa + wb, p)));
        }
        for (i, &s) in used.iter().enumerate() {
            let mut depth = 0u32;
            let mut node = i;
            while parent[node] != usize::MAX {
                depth += 1;
                node = parent[node];
            }
            if depth > MAX_CODE_LEN {
                return None;
            }
            lens[s] = depth;
        }
        Some(lens)
    }

    /// Canonical code values for symbols with nonzero length, assigned in
    /// `(len, symbol)` order.
    fn canonical_codes(lens: &[u32]) -> Vec<u64> {
        let mut syms: Vec<usize> = (0..256).filter(|&s| lens[s] > 0).collect();
        syms.sort_by_key(|&s| (lens[s], s));
        let mut codes = vec![0u64; 256];
        let mut code = 0u64;
        let mut prev_len = 0u32;
        for &s in &syms {
            code <<= lens[s] - prev_len;
            codes[s] = code;
            code += 1;
            prev_len = lens[s];
        }
        codes
    }

    fn huff_compress(src: &[u8]) -> Option<Vec<u8>> {
        if src.is_empty() {
            return None;
        }
        let mut freq = [0u64; 256];
        for &b in src {
            freq[b as usize] += 1;
        }
        let lens = code_lengths(&freq)?;
        let codes = canonical_codes(&lens);
        let mut out = Vec::with_capacity(src.len() / 2 + 16);
        out.push(MODE_HUFF);
        put_varint(&mut out, src.len() as u64);
        let mut used: Vec<usize> = (0..256).filter(|&s| lens[s] > 0).collect();
        used.sort_by_key(|&s| (lens[s], s));
        out.push((used.len() - 1) as u8);
        for &s in &used {
            out.push(s as u8);
            out.push(lens[s] as u8);
        }
        let mut acc: u64 = 0;
        let mut nbits: u32 = 0;
        for &b in src {
            acc = (acc << lens[b as usize]) | codes[b as usize];
            nbits += lens[b as usize];
            while nbits >= 8 {
                nbits -= 8;
                out.push(((acc >> nbits) & 0xff) as u8);
            }
        }
        if nbits > 0 {
            out.push(((acc << (8 - nbits)) & 0xff) as u8);
        }
        Some(out)
    }

    fn huff_decompress(src: &[u8], capacity: usize) -> io::Result<Vec<u8>> {
        let (n, _) = get_varint(src).ok_or_else(|| bad("truncated length"))?;
        let n = usize::try_from(n).map_err(|_| bad("length overflow"))?;
        if n > capacity {
            return Err(bad("decoded length exceeds capacity"));
        }
        let mut out = vec![0u8; n];
        let written = huff_decompress_into(src, &mut out)?;
        debug_assert_eq!(written, n);
        Ok(out)
    }

    const SLOTS: usize = MAX_CODE_LEN as usize + 1;
    /// Width of the table-driven decoder's primary lookup, in bits. Codes
    /// longer than this fall back to a canonical first/count walk; typical
    /// plane data stays well under 11 bits, so nearly every symbol is one
    /// table probe.
    const TABLE_BITS: usize = 11;

    /// Canonical-table view of a MODE_HUFF header, rebuilt on the stack
    /// (fixed 49-slot arrays; symbols ordered by `(len, symbol)` exactly as
    /// the encoder emitted them). Shared between the table-driven decoder
    /// and the bit-at-a-time reference so the two cannot diverge on header
    /// validation.
    struct HuffTable {
        count: [usize; SLOTS],        // symbols per code length
        start: [usize; SLOTS + 1],    // prefix sums into `syms`
        syms: [u8; 256],              // symbols grouped by length
        first: [u64; SLOTS],          // first canonical code value per length
        max_len: usize,
    }

    /// Parse `[varint n][k-1][k pairs]`, validate it against `capacity`,
    /// and rebuild the canonical table; returns the decoded length, the
    /// table, and the bitstream slice. Allocates nothing.
    fn parse_huff_header<'a>(
        src: &'a [u8],
        capacity: usize,
    ) -> io::Result<(usize, HuffTable, &'a [u8])> {
        let (n, varint_len) = get_varint(src).ok_or_else(|| bad("truncated length"))?;
        let n = usize::try_from(n).map_err(|_| bad("length overflow"))?;
        if n > capacity {
            return Err(bad("decoded length exceeds capacity"));
        }
        let rest = &src[varint_len..];
        let (&kb, rest) = rest.split_first().ok_or_else(|| bad("truncated table"))?;
        let k = kb as usize + 1;
        if rest.len() < 2 * k {
            return Err(bad("truncated symbol table"));
        }
        // Symbols sorted by (len, symbol) — the wire order IS that order,
        // but a corrupt table may violate it; sort via fixed-size counting
        // (lengths are <= MAX_CODE_LEN) to stay allocation-free.
        let mut count = [0usize; SLOTS];
        for i in 0..k {
            let len = rest[2 * i + 1] as u32;
            if len == 0 || len > MAX_CODE_LEN {
                return Err(bad("bad code length"));
            }
            count[len as usize] += 1;
        }
        // per-length symbol lists live in one flat [u8; 256] (k <= 256),
        // sliced by prefix sums; within a length, insertion keeps symbol
        // order only if the wire was sorted — sort each bucket after fill.
        let mut start = [0usize; SLOTS + 1];
        for l in 0..SLOTS {
            start[l + 1] = start[l] + count[l];
        }
        let mut syms = [0u8; 256];
        let mut fill = start; // next write slot per length
        for i in 0..k {
            let sym = rest[2 * i];
            let len = rest[2 * i + 1] as usize;
            syms[fill[len]] = sym;
            fill[len] += 1;
        }
        for l in 1..SLOTS {
            syms[start[l]..start[l + 1]].sort_unstable();
        }
        let bits = &rest[2 * k..];
        let max_len = (1..SLOTS).rev().find(|&l| count[l] > 0).unwrap_or(0);
        // Canonical layout: first code value per length.
        let mut first = [0u64; SLOTS];
        let mut code = 0u64;
        let mut prev_len = 0u32;
        for l in 1..=max_len {
            if count[l] == 0 {
                continue;
            }
            code <<= (l as u32) - prev_len;
            first[l] = code;
            code += count[l] as u64;
            prev_len = l as u32;
            if code > (1u64 << l) {
                return Err(bad("over-subscribed code table"));
            }
        }
        let table = HuffTable { count, start, syms, first, max_len };
        Ok((n, table, bits))
    }

    /// Decode a MODE_HUFF payload into the front of `dst`; returns the
    /// decoded length. Allocates nothing: the canonical table and a
    /// `2^TABLE_BITS`-entry primary lookup table both live on the stack.
    ///
    /// The decoder keeps a 64-bit MSB-aligned bit buffer and resolves one
    /// symbol per table probe (entry = `sym << 6 | len`, 0 = not a short
    /// code); codes longer than `TABLE_BITS` walk the canonical
    /// `first`/`count` arrays exactly like the reference. Error
    /// classification matches [`huff_decompress_into_scalar`] bit for bit:
    /// a code that would need bits past the end of the stream is
    /// "truncated bitstream", a prefix no code matches after `max_len`
    /// real bits is "invalid code".
    fn huff_decompress_into(src: &[u8], dst: &mut [u8]) -> io::Result<usize> {
        let (n, t, bits) = parse_huff_header(src, dst.len())?;
        let tb = t.max_len.min(TABLE_BITS);
        // Primary table over the top `tb` bits; 0 is the long-code/invalid
        // sentinel (impossible for a real entry: len >= 1).
        let mut table = [0u16; 1 << TABLE_BITS];
        for l in 1..=tb {
            for j in 0..t.count[l] {
                let sym = t.syms[t.start[l] + j];
                let entry = ((sym as u16) << 6) | l as u16;
                let base = ((t.first[l] + j as u64) as usize) << (tb - l);
                table[base..base + (1usize << (tb - l))].fill(entry);
            }
        }
        let mut acc: u64 = 0; // top `nbits` bits are real stream bits
        let mut nbits: u32 = 0;
        let mut pos = 0usize;
        let mut w = 0usize;
        while w < n {
            // refill: after this, either nbits > 56 (>= any code length,
            // since MAX_CODE_LEN = 48) or the stream is fully buffered
            while nbits <= 56 && pos < bits.len() {
                acc |= (bits[pos] as u64) << (56 - nbits);
                pos += 1;
                nbits += 8;
            }
            let e = table[(acc >> (64 - tb)) as usize];
            let (sym, l) = if e != 0 {
                ((e >> 6) as u8, (e & 0x3f) as usize)
            } else {
                let mut hit = None;
                for l in (tb + 1)..=t.max_len {
                    if t.count[l] == 0 {
                        continue;
                    }
                    let code = acc >> (64 - l);
                    if code >= t.first[l] && ((code - t.first[l]) as usize) < t.count[l] {
                        hit = Some((t.syms[t.start[l] + (code - t.first[l]) as usize], l));
                        break;
                    }
                }
                match hit {
                    Some(x) => x,
                    // No code matches this prefix. The bit-at-a-time
                    // reference consumes real bits one by one: it reaches
                    // "invalid code" only if max_len+1 real bits exist,
                    // otherwise it runs out first.
                    None if (nbits as usize) > t.max_len => return Err(bad("invalid code")),
                    None => return Err(bad("truncated bitstream")),
                }
            };
            if l as u32 > nbits {
                // the match used zero padding past the real stream
                return Err(bad("truncated bitstream"));
            }
            dst[w] = sym;
            w += 1;
            acc <<= l;
            nbits -= l as u32;
        }
        Ok(n)
    }

    /// Bit-at-a-time predecessor of [`huff_decompress_into`]. Reference for
    /// differential tests and the `perf_hotpaths` speedup gates; not a
    /// production path.
    fn huff_decompress_into_scalar(src: &[u8], dst: &mut [u8]) -> io::Result<usize> {
        let (n, t, bits) = parse_huff_header(src, dst.len())?;
        let mut w = 0usize;
        let mut code = 0u64;
        let mut len = 0usize;
        'outer: for byte_idx in 0..=bits.len() {
            if w == n {
                break;
            }
            if byte_idx == bits.len() {
                return Err(bad("truncated bitstream"));
            }
            let byte = bits[byte_idx];
            for bit_pos in (0..8).rev() {
                code = (code << 1) | ((byte >> bit_pos) & 1) as u64;
                len += 1;
                if len > t.max_len {
                    return Err(bad("invalid code"));
                }
                if t.count[len] > 0 && code >= t.first[len] {
                    let idx = (code - t.first[len]) as usize;
                    if idx < t.count[len] {
                        dst[w] = t.syms[t.start[len] + idx];
                        w += 1;
                        code = 0;
                        len = 0;
                        if w == n {
                            break 'outer;
                        }
                    }
                }
            }
        }
        if w != n {
            return Err(bad("truncated bitstream"));
        }
        Ok(n)
    }

    /// [`decompress_to_buffer`] routed through the bit-at-a-time reference
    /// decoder. Exists so differential tests and `perf_hotpaths` can
    /// measure the table-driven decoder against its predecessor on the
    /// full framed path.
    #[doc(hidden)]
    pub fn decompress_to_buffer_scalar(src: &[u8], dst: &mut [u8]) -> io::Result<usize> {
        let (&mode, rest) = src.split_first().ok_or_else(|| bad("empty stream"))?;
        match mode {
            MODE_RAW => {
                if rest.len() > dst.len() {
                    return Err(bad("raw payload exceeds capacity"));
                }
                dst[..rest.len()].copy_from_slice(rest);
                Ok(rest.len())
            }
            MODE_HUFF => huff_decompress_into_scalar(rest, dst),
            _ => Err(bad("bad mode byte")),
        }
    }

    #[cfg(test)]
    mod tests {
        use super::*;

        // Tiny xorshift so the tests need no external RNG.
        struct X(u64);
        impl X {
            fn next(&mut self) -> u64 {
                self.0 ^= self.0 << 13;
                self.0 ^= self.0 >> 7;
                self.0 ^= self.0 << 17;
                self.0
            }
        }

        fn roundtrip(data: &[u8]) {
            let enc = compress(data, 3).unwrap();
            let dec = decompress(&enc, data.len()).unwrap();
            assert_eq!(dec, data);
        }

        /// Keep interpreter-bound runs (`cargo miri test`) tractable.
        fn cases(full: usize) -> usize {
            if cfg!(miri) {
                full.min(8)
            } else {
                full
            }
        }

        #[test]
        fn roundtrips_all_shapes() {
            let mut x = X(0xDEADBEEF);
            for case in 0..cases(200) {
                let len = (x.next() % 5000) as usize;
                let mut data = vec![0u8; len];
                match case % 5 {
                    0 => {
                        for b in data.iter_mut() {
                            *b = x.next() as u8;
                        }
                    }
                    1 => { /* all zeros */ }
                    2 => {
                        for b in data.iter_mut() {
                            *b = b'a' + (x.next() % 20) as u8;
                        }
                    }
                    3 => {
                        for (i, b) in data.iter_mut().enumerate() {
                            *b = (i % 7) as u8;
                        }
                    }
                    _ => {
                        for b in data.iter_mut() {
                            *b = if x.next() % 20 == 0 { x.next() as u8 } else { 0 };
                        }
                    }
                }
                roundtrip(&data);
            }
        }

        #[test]
        fn single_symbol_and_empty() {
            roundtrip(&[]);
            roundtrip(&[42]);
            roundtrip(&[7; 4096]);
        }

        #[test]
        fn low_entropy_shrinks() {
            let mut x = X(99);
            let data: Vec<u8> = (0..16384).map(|_| b'a' + (x.next() % 20) as u8).collect();
            let enc = compress(&data, 3).unwrap();
            // log2(20) ~ 4.32 bits/byte; allow slack for the header
            assert!(enc.len() < data.len() * 6 / 10, "enc={}", enc.len());
        }

        #[test]
        fn garbage_errors() {
            assert!(decompress(&[], 10).is_err());
            assert!(decompress(&[9, 9, 9], 10).is_err());
            assert!(decompress(&[1, 2, 3, 4], 100).is_err());
            // valid header, truncated bitstream
            let enc = compress(&[5u8; 100], 3).unwrap();
            assert!(decompress(&enc[..enc.len() - 1], 100).is_err());
        }

        #[test]
        fn capacity_is_enforced() {
            let enc = compress(&[1, 2, 3, 4, 5], 3).unwrap();
            assert!(decompress(&enc, 2).is_err());
        }

        #[test]
        fn to_buffer_matches_alloc_path() {
            let mut x = X(0xC0FFEE);
            for case in 0..cases(100) {
                let len = (x.next() % 3000) as usize;
                let mut data = vec![0u8; len];
                if case % 2 == 0 {
                    for b in data.iter_mut() {
                        *b = (x.next() % 7) as u8; // compressible
                    }
                } else {
                    for b in data.iter_mut() {
                        *b = x.next() as u8; // raw bypass
                    }
                }
                let enc = compress(&data, 3).unwrap();
                let mut dst = vec![0xEEu8; len + 8];
                let n = decompress_to_buffer(&enc, &mut dst).unwrap();
                assert_eq!(n, len);
                assert_eq!(&dst[..n], &data[..]);
                if len > 0 {
                    let mut small = vec![0u8; len - 1];
                    assert!(decompress_to_buffer(&enc, &mut small).is_err());
                }
            }
        }

        #[test]
        fn table_decoder_matches_bit_reference() {
            let mut x = X(0xFEED5EED);
            for case in 0..cases(200) {
                let len = (x.next() % 4000) as usize;
                let mut data = vec![0u8; len];
                match case % 4 {
                    0 => {
                        for b in data.iter_mut() {
                            *b = b'a' + (x.next() % 20) as u8;
                        }
                    }
                    1 => {
                        for b in data.iter_mut() {
                            *b = (x.next() % 3) as u8; // very short codes
                        }
                    }
                    2 => {
                        for b in data.iter_mut() {
                            *b = x.next() as u8; // ~8-bit codes / raw bypass
                        }
                    }
                    _ => { /* all zeros: single 1-bit code */ }
                }
                let enc = compress(&data, 3).unwrap();
                let mut a = vec![0xAAu8; len + 4];
                let mut b = vec![0x55u8; len + 4];
                let ra = decompress_to_buffer(&enc, &mut a).unwrap();
                let rb = decompress_to_buffer_scalar(&enc, &mut b).unwrap();
                assert_eq!(ra, rb);
                assert_eq!(&a[..ra], &b[..rb]);
                assert_eq!(&a[..ra], &data[..]);
                // truncations and bit flips must classify identically
                if enc.len() > 2 {
                    let cut = &enc[..enc.len() - 1];
                    let mut ta = vec![0u8; len + 4];
                    let mut tbuf = vec![0u8; len + 4];
                    let ea = decompress_to_buffer(cut, &mut ta);
                    let eb = decompress_to_buffer_scalar(cut, &mut tbuf);
                    assert_eq!(ea.is_err(), eb.is_err());
                    if let (Err(ea), Err(eb)) = (ea, eb) {
                        assert_eq!(ea.to_string(), eb.to_string());
                    }
                    let mut flipped = enc.clone();
                    let pos = (x.next() as usize) % flipped.len();
                    flipped[pos] ^= 1 << (x.next() % 8);
                    let mut fa = vec![0u8; len + 4];
                    let mut fb = vec![0u8; len + 4];
                    let ea = decompress_to_buffer(&flipped, &mut fa);
                    let eb = decompress_to_buffer_scalar(&flipped, &mut fb);
                    match (ea, eb) {
                        (Ok(na), Ok(nb)) => {
                            assert_eq!(na, nb);
                            assert_eq!(&fa[..na], &fb[..nb]);
                        }
                        (Err(ea), Err(eb)) => assert_eq!(ea.to_string(), eb.to_string()),
                        (a, b) => panic!("decoder divergence: {a:?} vs {b:?}"),
                    }
                }
            }
        }

        #[test]
        fn long_code_slow_path() {
            // A skewed distribution (freq ~ Fibonacci) forces code lengths
            // past TABLE_BITS so the slow-path walk actually runs.
            let mut data = Vec::new();
            let mut a = 1u64;
            let mut b = 1u64;
            let cap: u64 = if cfg!(miri) { 300 } else { 30_000 };
            for sym in 0..24u8 {
                data.resize(data.len() + a.min(cap) as usize, sym);
                let c = a + b;
                a = b;
                b = c;
            }
            let enc = compress(&data, 3).unwrap();
            let mut fast = vec![0u8; data.len()];
            let mut slow = vec![0u8; data.len()];
            assert_eq!(
                decompress_to_buffer(&enc, &mut fast).unwrap(),
                decompress_to_buffer_scalar(&enc, &mut slow).unwrap()
            );
            assert_eq!(fast, slow);
            assert_eq!(fast, data);
        }

        #[test]
        fn to_buffer_rejects_garbage() {
            let mut dst = [0u8; 64];
            assert!(decompress_to_buffer(&[], &mut dst).is_err());
            assert!(decompress_to_buffer(&[9, 9, 9], &mut dst).is_err());
            assert!(decompress_to_buffer(&[1, 2, 3, 4], &mut dst).is_err());
            let enc = compress(&[5u8; 100], 3).unwrap();
            assert!(decompress_to_buffer(&enc[..enc.len() - 1], &mut dst).is_err());
        }
    }
}
