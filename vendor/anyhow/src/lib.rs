//! Minimal offline stand-in for the `anyhow` crate.
//!
//! The build runs against a fixed vendor set with no registry access, so
//! this crate supplies the subset of `anyhow` the workspace actually uses:
//! [`Error`], [`Result`], the [`anyhow!`] / [`bail!`] / [`ensure!`] macros,
//! and the [`Context`] extension trait for `Result` and `Option`.
//!
//! Error values carry a flattened message chain (context is prepended as
//! `"context: cause"`); there is no backtrace capture. That is sufficient
//! for every call site in `trace_cxl`, which formats errors with `{}` /
//! `{:#}` and never downcasts.

use std::fmt;

/// A string-backed error value, API-compatible with `anyhow::Error` for
/// the operations this workspace performs (construct, contextualize,
/// display).
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from any displayable message.
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { msg: m.to_string() }
    }

    /// Prepend context, mirroring `anyhow`'s `"{context}: {cause}"` chain.
    pub fn context<C: fmt::Display>(self, ctx: C) -> Error {
        Error { msg: format!("{ctx}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// `Error` deliberately does NOT implement `std::error::Error`; exactly like
// the real `anyhow`, that is what makes this blanket `From` coherent.
impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        Error::msg(e.to_string())
    }
}

/// `Result<T, anyhow::Error>` with the error type defaulted.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Context-attachment extension for fallible values.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| Error { msg: format!("{ctx}: {e}") })
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error { msg: format!("{}: {e}", f()) })
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an error built by [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)+) => {
        return Err($crate::anyhow!($($arg)+))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::anyhow!("condition failed: `{}`", stringify!($cond)));
        }
    };
    ($cond:expr, $($arg:tt)+) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn may_fail(x: i32) -> Result<i32> {
        ensure!(x >= 0, "negative input {x}");
        if x == 1 {
            bail!("one is not allowed");
        }
        Ok(x * 2)
    }

    #[test]
    fn macros_and_display() {
        assert_eq!(may_fail(2).unwrap(), 4);
        assert_eq!(may_fail(-3).unwrap_err().to_string(), "negative input -3");
        assert_eq!(may_fail(1).unwrap_err().to_string(), "one is not allowed");
        let e = anyhow!("v={}", 7);
        assert_eq!(format!("{e}"), "v=7");
        assert_eq!(format!("{e:#}"), "v=7");
        assert_eq!(format!("{e:?}"), "v=7");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn parse(s: &str) -> Result<i32> {
            Ok(s.parse::<i32>()?)
        }
        assert_eq!(parse("41").unwrap(), 41);
        assert!(parse("nope").is_err());
    }

    #[test]
    fn context_chains() {
        let r: std::result::Result<(), std::fmt::Error> = Err(std::fmt::Error);
        let e = r.context("outer").unwrap_err();
        assert!(e.to_string().starts_with("outer: "));
        let n: Option<i32> = None;
        assert_eq!(n.context("missing").unwrap_err().to_string(), "missing");
        let w: Option<i32> = None;
        assert_eq!(w.with_context(|| format!("k={}", 3)).unwrap_err().to_string(), "k=3");
    }
}
