//! Elastic precision scenario (Mechanism II end to end): a runtime keeps
//! KV pages at mixed precision tiers; the device serves each tier by
//! fetching only the planes that view needs, and on-device guard-plane
//! rounding preserves accuracy versus naive truncation.
//!
//! Also replays the same fetch plan through the DRAM simulator to show
//! the physical activation/energy savings of plane-aligned fetch.
//!
//! Run: `cargo run --release --example elastic_precision`

use trace_cxl::bitplane::{DeviceBlock, KvWindow, PrecisionView};
use trace_cxl::codec::CodecPolicy;
use trace_cxl::dram::layout::{plane_fetch_requests, unit_scales, word_fetch_requests, ChunkFetch, Region};
use trace_cxl::dram::{AddrMap, DramConfig, DramSim, EnergyParams};
use trace_cxl::formats::bf16_to_f32;
use trace_cxl::gen::KvGen;
use trace_cxl::tier::PageTier;
use trace_cxl::util::Rng;

fn main() -> anyhow::Result<()> {
    let mut rng = Rng::new(4);
    let kv = KvGen::default_for(64).generate(&mut rng, 64);
    let blk = DeviceBlock::encode_kv(&kv, KvWindow::new(64, 64), CodecPolicy::AllBest);
    let full: Vec<f32> = blk.decode_full()?.iter().map(|&w| bf16_to_f32(w)).collect();

    println!("== tier ladder on one KV page ==");
    println!("{:<8} {:>14} {:>14} {:>16}", "tier", "fetch bytes", "rel. error", "w/ guard round");
    for tier in [PageTier::Bf16, PageTier::Fp8, PageTier::Fp4] {
        let v = tier.view().unwrap();
        let vt = PrecisionView { d_m: 0, ..v }; // truncation-only variant
        let bytes = blk.fetched_bytes(v.mask());
        let err = |view: &PrecisionView| -> anyhow::Result<f64> {
            let got = blk.decode_view(view)?;
            let num: f64 = got
                .iter()
                .zip(&full)
                .map(|(&w, &f)| ((bf16_to_f32(w) - f) as f64).powi(2))
                .sum();
            let den: f64 = full.iter().map(|&f| (f as f64).powi(2)).sum();
            Ok((num / den).sqrt())
        };
        println!(
            "{:<8} {:>14} {:>14.5} {:>16.5}",
            format!("{tier:?}"),
            bytes,
            err(&vt)?,
            err(&v)?
        );
    }

    println!("\n== plane-aligned fetch vs word fetch in DRAM (16 chunks @ 4.8 avg bits) ==");
    let cfg = DramConfig::paper_default();
    let map = AddrMap::new(cfg);
    let region = Region { base: 0, elems: 262_144, container_bits: 16 };
    let fetches: Vec<ChunkFetch> = (0..16)
        .map(|c| ChunkFetch { chunk: c, bits: if c < 4 { 9 } else { 4 } })
        .collect();
    let mut s1 = DramSim::new(cfg, EnergyParams::ddr5_4800());
    let word = s1.run_frfcfs(word_fetch_requests(&map, region, &fetches, 0.0), 16);
    let mut s2 = DramSim::new(cfg, EnergyParams::ddr5_4800());
    let plane =
        s2.run_frfcfs(plane_fetch_requests(&map, region, 16, &fetches, &unit_scales(16), 0.0), 16);
    println!(
        "word fetch : {:>8.2} ms, {:>6} activations, {:>8.2} mJ",
        word.finish_ns / 1e6,
        word.activations,
        word.energy.total_pj() / 1e9
    );
    println!(
        "plane fetch: {:>8.2} ms, {:>6} activations, {:>8.2} mJ  ({:.1}% energy saved)",
        plane.finish_ns / 1e6,
        plane.activations,
        plane.energy.total_pj() / 1e9,
        100.0 * (1.0 - plane.energy.total_pj() / word.energy.total_pj())
    );
    anyhow::ensure!(plane.energy.total_pj() < word.energy.total_pj());
    println!("\nLower tiers fetch fewer planes; guard-plane rounding recovers most of the");
    println!("truncation error at negligible extra traffic (paper §III-C).");
    Ok(())
}
