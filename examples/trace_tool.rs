//! Trace tooling CLI: record named scenarios, decode captures, replay
//! them bit-identically, and diff two traces.
//!
//! Subcommands:
//!
//! * `record` — run a named scenario (`gen::scenarios`) through a mock
//!   engine with a trace sink attached and write the capture.
//! * `decode` — parse a trace, print the run summary and the first
//!   records (validates the whole stream: truncation/corruption errors).
//! * `replay` — rebuild the captured engine from the trace header
//!   (`CaptureMeta`), re-drive the recorded submissions, and fail unless
//!   the re-run matches the capture bit-for-bit.
//! * `diff` — compare two traces (submissions, token streams,
//!   TTFT/TPOT, device traffic) and fail on any divergence.
//!
//! Format: docs/TRACE_FORMAT.md. Capture semantics: docs/SERVING.md.
//!
//! Run: `cargo run --release --example trace_tool -- record --out run.trc --scenario rag-fanout`

use anyhow::{anyhow, ensure, Result};
use trace_cxl::coordinator::SchedKind;
use trace_cxl::gen::scenarios;
use trace_cxl::runtime::ModelDims;
use trace_cxl::trace::{diff, resubmit, CaptureMeta, Trace, TraceWriter};
use trace_cxl::util::cli::Args;
use trace_cxl::util::stats::human_bytes;

fn main() -> Result<()> {
    let args = Args::from_env();
    match args.subcommand.as_deref() {
        Some("record") => record(&args),
        Some("decode") => decode(&args),
        Some("replay") => replay(&args),
        Some("diff") => cmd_diff(&args),
        _ => {
            print_help();
            Ok(())
        }
    }
}

fn print_help() {
    println!(
        "trace_tool — binary serving-trace capture/replay/diff\n\
         USAGE: cargo run --release --example trace_tool -- <record|decode|replay|diff> [--options]\n\
         \n\
         record  --out FILE [--scenario NAME] [--seed N] [--requests N] [--max-new N]\n\
         \x20        [--shards N] [--policy fcfs|sjf|priority] [--overlap] [--hbm-kv BYTES]\n\
         decode  --in FILE [--limit N]\n\
         replay  --in FILE [--out FILE]\n\
         diff    --a FILE --b FILE\n\
         \n\
         scenarios: {}",
        scenarios::names()
    );
}

fn in_file(args: &Args) -> Result<Vec<u8>> {
    let path = args.get("in").ok_or_else(|| anyhow!("missing --in FILE"))?;
    Ok(std::fs::read(path)?)
}

/// Recording dims: small enough to run in milliseconds, prompts long
/// enough (vs `tier::PAGE_TOKENS`) that rag-fanout actually shares pages.
fn record_dims() -> ModelDims {
    ModelDims {
        layers: 2,
        batch: 4,
        t_max: 256,
        t_prompt: 112,
        d_model: 16,
        heads: 2,
        head_dim: 4,
        ffn: 32,
        vocab: 64,
    }
}

fn record(args: &Args) -> Result<()> {
    let out = args.get("out").ok_or_else(|| anyhow!("record needs --out FILE"))?;
    let name = args.get_or("scenario", "diurnal").to_string();
    let sc = scenarios::by_name(&name).ok_or_else(|| {
        anyhow!("unknown --scenario '{name}' (one of: {})", scenarios::names())
    })?;
    let seed = args.get_u64("seed", 11);
    let n = args.get_usize("requests", 12);
    let max_new = args.get_usize("max-new", 16);
    let dims = record_dims();

    let mut meta = CaptureMeta::mock(dims.clone(), 42);
    // a ~2-page HBM KV budget forces the CXL spill path early
    meta.hbm_kv_bytes = args.get_u64("hbm-kv", (dims.kv_entry_len() * 2 * 20) as u64);
    meta.shards = args.get_usize("shards", 1).max(1);
    meta.overlap = args.flag("overlap");
    meta.sched = SchedKind::parse(args.get_or("policy", "fcfs"))
        .ok_or_else(|| anyhow!("unknown --policy (fcfs|sjf|priority)"))?;
    meta.scenario = Some(name.clone());
    meta.gen_seed = seed;

    let mut engine = meta.build_mock_engine()?;
    engine.set_trace_sink(TraceWriter::new(&meta.to_json()));
    let cap = max_new.min(dims.t_max.saturating_sub(dims.t_prompt + 2)).max(1);
    for r in sc.generate(seed, n, dims.vocab as u32, dims.t_prompt, cap) {
        match r.prefix {
            Some(p) => engine.submit_shared_at(r.prompt, r.max_new, r.arrival_ns, r.sla, p),
            None => engine.submit_at(r.prompt, r.max_new, r.arrival_ns, r.sla),
        };
    }
    engine.run_to_completion(400_000)?;
    ensure!(
        engine.metrics.requests_finished as usize == n,
        "recording must run the whole scenario to completion"
    );
    let w = engine.take_trace_sink().expect("sink installed above");
    let records = w.records();
    let bytes = w.finish();
    std::fs::write(out, &bytes)?;
    println!(
        "recorded scenario '{name}' (seed {seed}, {n} requests): {records} records, {} -> {out}",
        human_bytes(bytes.len() as f64)
    );
    Ok(())
}

fn decode(args: &Args) -> Result<()> {
    let bytes = in_file(args)?;
    let t = Trace::parse(&bytes)?;
    println!("{}", t.summary());
    let limit = args.get_usize("limit", 20);
    for r in t.records.iter().take(limit) {
        println!("  {r:?}");
    }
    if t.records.len() > limit {
        println!("  ... {} more records (raise --limit to see them)", t.records.len() - limit);
    }
    Ok(())
}

fn replay(args: &Args) -> Result<()> {
    let bytes = in_file(args)?;
    let captured = Trace::parse(&bytes)?;
    let meta = CaptureMeta::from_json(&captured.meta)?;
    let mut engine = meta.build_mock_engine()?;
    engine.set_trace_sink(TraceWriter::new(&captured.meta));
    let n = resubmit(&mut engine, &captured);
    engine.run_to_completion(400_000)?;
    let w = engine.take_trace_sink().expect("sink installed above");
    let replayed_bytes = w.finish();
    if let Some(out) = args.get("out") {
        std::fs::write(out, &replayed_bytes)?;
    }
    let replayed = Trace::parse(&replayed_bytes)?;
    let d = diff(&captured, &replayed);
    ensure!(d.is_empty(), "replay diverged from the capture:\n{}", d.report());
    println!(
        "replay OK: {n} submissions re-driven, {} records match the capture bit-for-bit",
        replayed.records.len()
    );
    Ok(())
}

fn cmd_diff(args: &Args) -> Result<()> {
    let pa = args.get("a").ok_or_else(|| anyhow!("diff needs --a FILE"))?;
    let pb = args.get("b").ok_or_else(|| anyhow!("diff needs --b FILE"))?;
    let a = Trace::parse(&std::fs::read(pa)?)?;
    let b = Trace::parse(&std::fs::read(pb)?)?;
    let d = diff(&a, &b);
    println!("{}", d.report());
    ensure!(d.is_empty(), "traces differ ({} line(s) above)", d.lines.len());
    Ok(())
}
