//! End-to-end driver (the harness-mandated validation): load the real
//! ~100M-parameter AOT-compiled transformer, serve batched requests
//! through the full three-layer stack, spill KV to the simulated TRACE
//! CXL device, and report latency/throughput + device traffic.
//!
//! Layers exercised: L1 Pallas decode-attention (inside the HLO), L2 JAX
//! model (compiled once by `make artifacts`), L3 Rust coordinator + tier
//! manager + TRACE device model. Python is NOT on this path.
//!
//! Run: `make artifacts && cargo run --release --example serve_e2e`
//! Results are recorded in EXPERIMENTS.md §End-to-end.

use trace_cxl::codec::CodecPolicy;
use trace_cxl::coordinator::{Engine, EngineConfig};
use trace_cxl::cxl::Design;
use trace_cxl::gen::SynthCorpus;
use trace_cxl::runtime::{ModelBackend, PjrtEngine};
use trace_cxl::tier::KvPolicy;
use trace_cxl::util::cli::Args;
use trace_cxl::util::stats::human_bytes;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let dir = std::path::PathBuf::from(args.get_or("artifacts", "artifacts"));
    let n_requests = args.get_usize("requests", 6);
    let max_new = args.get_usize("max-new", 64);

    println!("== serve_e2e: full-stack serving on the AOT model ==");
    println!("loading + compiling artifacts from {dir:?} ...");
    let t0 = std::time::Instant::now();
    let backend = PjrtEngine::load(&dir)?;
    let dims = backend.dims().clone();
    println!(
        "compiled in {:.1}s — {} layers, d_model {}, vocab {} (~{:.0}M params), batch {}, t_max {}",
        t0.elapsed().as_secs_f64(),
        dims.layers,
        dims.d_model,
        dims.vocab,
        dims.param_count() as f64 / 1e6,
        dims.batch,
        dims.t_max,
    );

    // HBM KV budget of ~1 page so long sequences MUST spill to the CXL
    // tier early and the decode loop recalls pages through the device.
    let hbm_kv = args.get_u64("hbm-kv", (dims.kv_entry_len() * 2 * 20) as u64);
    let mut engine = Engine::new(
        backend,
        EngineConfig {
            design: Design::Trace,
            codec: CodecPolicy::FastBest,
            hbm_kv_bytes: hbm_kv,
            policy: KvPolicy::FullKv,
            greedy: true,
        },
    );

    let mut corpus = SynthCorpus::new(dims.vocab as u32, 7);
    for i in 0..n_requests {
        let plen = 8 + (i * 5) % (dims.t_prompt - 8);
        let prompt = corpus.take(plen);
        let new = max_new.min(dims.t_max - dims.t_prompt - 2);
        engine.submit(prompt, new);
    }
    println!(
        "submitted {n_requests} requests (max_new={max_new}, HBM-KV budget {})",
        human_bytes(hbm_kv as f64)
    );

    engine.run_to_completion(50_000)?;
    let responses = engine.take_responses();

    println!("\n-- results --");
    for r in &responses {
        println!(
            "req {:>2}: prompt {:>3} tokens -> generated {:>3} tokens (in flight {} steps)",
            r.id,
            r.prompt_len,
            r.tokens.len(),
            r.steps_in_flight
        );
    }
    let m = &engine.metrics;
    let s = m.step_latency();
    println!("\n-- throughput / latency --");
    println!(
        "tokens generated: {}   wall {:.1}s   {:.2} tok/s   step p50 {:.1} ms p99 {:.1} ms",
        m.tokens_generated,
        m.elapsed_s(),
        m.tok_per_s(),
        s.p50,
        s.p99
    );
    println!("\n-- memory tier --");
    println!(
        "KV pages: {} in HBM, {} spilled to CXL; recalled {} from the device",
        m.pages_hbm,
        m.pages_spilled,
        human_bytes(m.kv_recall_bytes as f64)
    );
    let d = &engine.device.stats;
    println!(
        "device: dram_wr {} dram_rd {} link_out {} (KV compression ratio {:.2}x over {} blocks)",
        human_bytes(d.dram_bytes_written as f64),
        human_bytes(d.dram_bytes_read as f64),
        human_bytes(d.link_bytes_out as f64),
        engine.device.overall_ratio(),
        engine.device.len()
    );
    anyhow::ensure!(m.requests_finished as usize == n_requests, "all requests must finish");
    anyhow::ensure!(m.pages_spilled > 0, "workload must exercise the CXL spill path");
    anyhow::ensure!(engine.device.overall_ratio() > 1.0, "real model KV must compress");
    println!("\nOK: all layers composed; KV spilled to the TRACE device and came back bit-exact.");
    Ok(())
}
