//! End-to-end driver: serve batched requests through the full three-layer
//! stack, spill KV to the simulated TRACE CXL tier (optionally sharded
//! with `--shards N`), and report latency/throughput + device traffic.
//!
//! Scheduling is pluggable (`--policy fcfs|sjf|priority`). With `--rate R`
//! the driver replays an open-loop Poisson arrival trace (R requests per
//! model-time second, `--interactive-frac` of them in the interactive QoS
//! class with quarter-length decodes) through `Engine::submit_at`, and
//! reports offered vs served load plus the per-class latency breakdown.
//! Without `--rate` every request is submitted at model time 0, as the
//! earlier revisions did. `--scenario NAME` instead draws the workload
//! from the named scenario library (`gen::scenarios`; rag-fanout
//! exercises refcounted shared-prefix KV pages), `--seed` controls every
//! generator path, and `--trace-out FILE` captures the whole run as a
//! compact binary trace replayable with `--example trace_tool`
//! (docs/TRACE_FORMAT.md).
//!
//! `--nmc` turns on the near-memory fetch planner: spilled full-precision
//! page reads may be offloaded to device-side `ReduceKv` transactions
//! (top-k rows travel the link instead of the whole page). Tokens are
//! bit-identical either way; the flag is recorded in the capture meta so
//! `--trace-out` traces replay with the same planner state.
//!
//! With AOT artifacts present (`make artifacts`, requires the `pjrt`
//! feature) the real ~100M-parameter compiled transformer serves the
//! requests; otherwise the deterministic mock backend runs the identical
//! coordinator/tier/device path, so the example always exercises the
//! transaction API end-to-end.
//!
//! Run: `cargo run --release --example serve_e2e -- --shards 4 --policy priority --rate 20000`
//! Results are recorded in EXPERIMENTS.md §End-to-end.

use trace_cxl::codec::CodecPolicy;
use trace_cxl::coordinator::{Engine, EngineConfig, SchedKind, SlaClass};
use trace_cxl::cxl::{Design, MemDevice};
use trace_cxl::gen::{scenarios, RequestGen, SynthCorpus};
use trace_cxl::runtime::{MockBackend, ModelBackend, PjrtEngine};
use trace_cxl::tier::KvPolicy;
use trace_cxl::trace::{CaptureMeta, TraceWriter};
use trace_cxl::util::cli::Args;
use trace_cxl::util::stats::human_bytes;
use trace_cxl::util::Rng;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let dir = std::path::PathBuf::from(args.get_or("artifacts", "artifacts"));

    println!("== serve_e2e: full-stack serving through the transaction API ==");
    let t0 = std::time::Instant::now();
    match PjrtEngine::load(&dir) {
        Ok(backend) => {
            println!("compiled artifacts from {dir:?} in {:.1}s", t0.elapsed().as_secs_f64());
            run(backend, &args, "pjrt")
        }
        Err(e) => {
            println!("note: {e}");
            println!("falling back to the deterministic mock backend\n");
            run(MockBackend::tiny(), &args, "mock")
        }
    }
}

fn run<B: ModelBackend>(backend: B, args: &Args, backend_name: &str) -> anyhow::Result<()> {
    let dims = backend.dims().clone();
    let n_requests = args.get_usize("requests", 6);
    let max_new = args.get_usize("max-new", 64);
    let shards = args.get_usize("shards", 1).max(1);
    let sched = SchedKind::parse(args.get_or("policy", "fcfs"))
        .ok_or_else(|| anyhow::anyhow!("unknown --policy (fcfs|sjf|priority)"))?;
    let rate = args.get_f64("rate", 0.0);
    let interactive_frac = args.get_f64("interactive-frac", 0.5);
    let seed = args.get_u64("seed", 11);
    let scenario = args.get("scenario").map(str::to_string);
    let compute_ns = args.get_f64("compute-ns", 2000.0);
    println!(
        "model: {} layers, d_model {}, vocab {} (~{:.1}M params), batch {}, t_max {}",
        dims.layers,
        dims.d_model,
        dims.vocab,
        dims.param_count() as f64 / 1e6,
        dims.batch,
        dims.t_max,
    );

    // HBM KV budget of ~1 page so long sequences MUST spill to the CXL
    // tier early and the decode loop recalls pages through the device.
    let hbm_kv = args.get_u64("hbm-kv", (dims.kv_entry_len() * 2 * 20) as u64);
    let overlap = args.flag("overlap");
    let nmc = args.flag("nmc");
    // --faults SEED: run the whole workload under a seeded chaos plan
    // (bit flips, metadata corruption, transients, stalls — all
    // repairable: guards + retries are on, docs/FAULTS.md). The serving
    // results must be bit-identical to a fault-free run.
    let faults = match args.get("faults") {
        Some(s) => Some(trace_cxl::cxl::FaultPlan::chaos(
            s.parse::<u64>().map_err(|_| anyhow::anyhow!("--faults takes a seed"))?,
        )),
        None => None,
    };
    let mut engine = Engine::new(
        backend,
        EngineConfig {
            design: Design::Trace,
            codec: CodecPolicy::FastBest,
            hbm_kv_bytes: hbm_kv,
            policy: KvPolicy::FullKv,
            greedy: true,
            shards,
            overlap,
            compute_ns,
            sched,
            nmc,
            faults,
            ..Default::default()
        },
    );
    let trace_out = args.get("trace-out").map(std::path::PathBuf::from);
    if trace_out.is_some() {
        // MockBackend::tiny() is seeded 42; replay rebuilds it from here
        let mut meta = CaptureMeta::mock(dims.clone(), 42);
        meta.backend = backend_name.to_string();
        meta.hbm_kv_bytes = hbm_kv;
        meta.shards = shards;
        meta.overlap = overlap;
        meta.sched = sched;
        meta.compute_ns = compute_ns;
        meta.scenario = scenario.clone();
        meta.gen_seed = seed;
        meta.nmc = nmc;
        meta.faults = faults;
        engine.set_trace_sink(TraceWriter::new(&meta.to_json()));
    }

    let cap = max_new.min(dims.t_max.saturating_sub(dims.t_prompt + 2)).max(1);
    let mut offered_span_ns = 0.0f64;
    if let Some(name) = &scenario {
        let sc = scenarios::by_name(name).ok_or_else(|| {
            anyhow::anyhow!("unknown --scenario '{name}' (one of: {})", scenarios::names())
        })?;
        for r in sc.generate(seed, n_requests, dims.vocab as u32, dims.t_prompt, cap) {
            offered_span_ns = offered_span_ns.max(r.arrival_ns);
            match r.prefix {
                Some(p) => engine.submit_shared_at(r.prompt, r.max_new, r.arrival_ns, r.sla, p),
                None => engine.submit_at(r.prompt, r.max_new, r.arrival_ns, r.sla),
            };
        }
        println!(
            "submitted {n_requests} requests from scenario '{name}' (seed {seed}) over {:.1} us, \
             policy {}, HBM-KV {}, {} shard(s), {} pipeline",
            offered_span_ns / 1000.0,
            sched.name(),
            human_bytes(hbm_kv as f64),
            shards,
            if overlap { "overlapped" } else { "serial" }
        );
    } else if rate > 0.0 {
        // open-loop Poisson arrivals: the engine's clock must reach an
        // arrival before the scheduler may admit it
        let mut rng = Rng::new(seed);
        let gen = RequestGen::new(rate, 2, dims.t_prompt, max_new, dims.vocab as u32);
        for r in gen.generate(&mut rng, n_requests) {
            let interactive = rng.chance(interactive_frac);
            let (sla, decode) = if interactive {
                (SlaClass::Interactive, (cap / 4).max(1))
            } else {
                (SlaClass::Batch, cap)
            };
            offered_span_ns = offered_span_ns.max(r.arrival_ns());
            engine.submit_at(r.prompt, decode, r.arrival_ns(), sla);
        }
        println!(
            "submitted {n_requests} requests open-loop at {rate:.0} req/s over {:.1} us \
             ({:.0}% interactive), policy {}, HBM-KV {}, {} shard(s), {} pipeline",
            offered_span_ns / 1000.0,
            interactive_frac * 100.0,
            sched.name(),
            human_bytes(hbm_kv as f64),
            shards,
            if overlap { "overlapped" } else { "serial" }
        );
    } else {
        let mut corpus = SynthCorpus::new(dims.vocab as u32, seed);
        let prompt_span = dims.t_prompt.saturating_sub(2).max(1);
        for i in 0..n_requests {
            let plen = (2 + (i * 5) % prompt_span).min(dims.t_prompt);
            let prompt = corpus.take(plen);
            engine.submit(prompt, cap);
        }
        println!(
            "submitted {n_requests} requests (max_new={max_new}, policy {}, HBM-KV budget {}, {} shard(s), {} pipeline)",
            sched.name(),
            human_bytes(hbm_kv as f64),
            shards,
            if overlap { "overlapped" } else { "serial" }
        );
    }

    engine.run_to_completion(200_000)?;
    if let Some(path) = &trace_out {
        let w = engine.take_trace_sink().expect("trace sink was installed above");
        let records = w.records();
        let bytes = w.finish();
        std::fs::write(path, &bytes)?;
        println!(
            "trace: {records} records, {} -> {}",
            human_bytes(bytes.len() as f64),
            path.display()
        );
    }
    let responses = engine.take_responses();

    println!("\n-- results --");
    for r in &responses {
        println!(
            "req {:>2}: prompt {:>3} tokens -> generated {:>3} tokens (in flight {} steps)",
            r.id,
            r.prompt_len,
            r.tokens.len(),
            r.steps_in_flight
        );
    }
    let m = &engine.metrics;
    let s = m.step_latency();
    println!("\n-- throughput / latency --");
    println!(
        "tokens generated: {}   wall {:.1}s   {:.2} tok/s   step p50 {:.1} ms p99 {:.1} ms",
        m.tokens_generated,
        m.elapsed_s(),
        m.tok_per_s(),
        s.p50,
        s.p99
    );
    let ms = m.model_step_latency();
    println!(
        "model time: {:.2} ms simulated   {:.2} tok/s   step p50 {:.2} us p99 {:.2} us",
        m.model_ns / 1e6,
        m.model_tok_per_s(),
        ms.p50 / 1000.0,
        ms.p99 / 1000.0
    );
    println!(
        "request model-time latency: TTFT p50 {:.2} us p99 {:.2} us   TPOT p50 {:.2} us p99 {:.2} us",
        m.ttft().p50 / 1000.0,
        m.ttft().p99 / 1000.0,
        m.tpot().p50 / 1000.0,
        m.tpot().p99 / 1000.0
    );
    if rate > 0.0 {
        // offered vs served: arrival-window request rate vs what the
        // engine actually sustained in model time
        let offered = n_requests as f64 / (offered_span_ns * 1e-9).max(1e-12);
        let served = m.requests_finished as f64 / m.model_elapsed_s().max(1e-12);
        println!(
            "load: offered {:.0} req/s over the arrival window, served {:.0} req/s end-to-end ({:.2}x)",
            offered,
            served,
            offered / served.max(1e-12)
        );
        println!(
            "queue delay: p50 {:.2} us p99 {:.2} us   sched: {} preemptions, {} resumes, {} idle jumps, restore {}",
            m.queue_delay().p50 / 1000.0,
            m.queue_delay().p99 / 1000.0,
            m.preemptions,
            m.resumes,
            m.idle_jumps,
            human_bytes(m.restore_bytes as f64)
        );
        for class in SlaClass::ALL {
            let t = m.ttft_class(class);
            if t.n > 0 {
                println!(
                    "  {:<12} {:>2} finished   TTFT p50 {:>9.2} us p99 {:>9.2} us",
                    class.name(),
                    t.n,
                    t.p50 / 1000.0,
                    t.p99 / 1000.0
                );
            }
        }
    }
    if overlap {
        println!(
            "prefetch pipeline: {} issued, {} consumed, {} stale-discarded",
            m.prefetch_issued, m.prefetch_hits, m.prefetch_stale
        );
    }
    if nmc {
        let d = engine.device.stats();
        println!(
            "near-memory offload: {} fetches ({} interactive / {} batch), \
             link reads saved {}, device scan {}",
            m.nmc_offloads,
            m.nmc_offloads_class[SlaClass::Interactive.index()],
            m.nmc_offloads_class[SlaClass::Batch.index()],
            human_bytes(m.link_bytes_saved as f64),
            human_bytes(d.nmc_bytes_scanned as f64)
        );
    }
    if faults.is_some() {
        let d = engine.device.stats();
        println!(
            "chaos: {} injected, {} detected, {} repaired, {} retried, {} failed over; \
             engine failovers {}, requeues {}, pages degraded {}",
            d.faults_injected,
            d.faults_detected,
            d.faults_repaired,
            d.faults_retried,
            d.faults_failed_over,
            m.fault_failovers,
            m.fault_requeues,
            m.pages_degraded
        );
    }
    if let Some(path) = args.get("faults-report") {
        let json = m.to_json(&engine.device.stats());
        let report = json.get("faults").cloned().unwrap_or(trace_cxl::util::json::Json::Null);
        std::fs::write(path, report.to_string())?;
        println!("faults report -> {path}");
    }
    if args.flag("json") {
        println!("\n-- metrics.json --\n{}", m.to_json(&engine.device.stats()));
    }
    println!("\n-- memory tier --");
    println!(
        "KV pages: {} in HBM, {} spilled to CXL; recalled {} from the device",
        m.pages_hbm,
        m.pages_spilled,
        human_bytes(m.kv_recall_bytes as f64)
    );
    let d = engine.device.stats();
    // finished sequences free their device blocks, so footprint-based
    // ratio is over live blocks only; report the lifetime compression
    let lifetime_ratio = d.lifetime_compression_ratio();
    println!(
        "device: dram_wr {} dram_rd {} link_out {} (lifetime KV compression {:.2}x; {} live blocks after retire)",
        human_bytes(d.dram_bytes_written as f64),
        human_bytes(d.dram_bytes_read as f64),
        human_bytes(d.link_bytes_out as f64),
        lifetime_ratio,
        engine.device.len()
    );
    if engine.device.shards() > 1 {
        println!("\n-- per-shard traffic --");
        for (i, st) in engine.device.shard_stats().iter().enumerate() {
            println!(
                "shard {:>2}: wr {:>10} rd {:>10} reads {:>5} writes {:>5}",
                i,
                human_bytes(st.dram_bytes_written as f64),
                human_bytes(st.dram_bytes_read as f64),
                st.reads,
                st.writes
            );
        }
        let busy = engine.device.shard_stats().iter().filter(|s| s.reads + s.writes > 0).count();
        anyhow::ensure!(busy >= 2, "sharded run must spread traffic over shards");
    }
    anyhow::ensure!(m.requests_finished as usize == n_requests, "all requests must finish");
    anyhow::ensure!(m.pages_spilled > 0, "workload must exercise the CXL spill path");
    if faults.is_some() {
        // the chaos gate: every injected fault is repairable by design,
        // so a degraded request or an unrecoverable block is a bug
        let d = engine.device.stats();
        anyhow::ensure!(d.faults_unrecoverable == 0, "chaos plan must stay repairable");
        anyhow::ensure!(m.requests_degraded == 0, "no request may finish degraded");
    }
    anyhow::ensure!(lifetime_ratio > 1.0, "model KV must compress");
    anyhow::ensure!(
        engine.device.len() == 0,
        "finished sequences must reclaim their device blocks"
    );
    println!("\nOK: all layers composed; KV spilled through the transaction queue and came back bit-exact.");
    Ok(())
}
