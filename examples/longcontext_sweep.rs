//! Long-context scenario: the paper's Fig. 12 story told through both the
//! analytic model and the functional device.
//!
//! For a sweep of context lengths we (a) evaluate the trace-driven
//! throughput model and (b) actually push the spilled KV volume through
//! the functional TRACE device (write path: transform + compress) on
//! calibrated tensors, reporting the measured compression ratio the model
//! consumes — closing the loop between §IV-B and §IV-C.
//!
//! Run: `cargo run --release --example longcontext_sweep`

use trace_cxl::bitplane::{DeviceBlock, KvWindow};
use trace_cxl::codec::CodecPolicy;
use trace_cxl::cxl::Design;
use trace_cxl::gen::KvGen;
use trace_cxl::sysmodel::{ModelShape, SystemConfig, ThroughputModel};
use trace_cxl::util::Rng;

fn main() -> anyhow::Result<()> {
    let mut rng = Rng::new(3);

    // (b) measure the device-side KV ratio on calibrated tensors
    let mut raw = 0usize;
    let mut comp = 0usize;
    for layer in 0..8 {
        let g = KvGen::for_layer(64, layer * 4, 32);
        let kv = g.generate(&mut rng, 64);
        let blk = DeviceBlock::encode_kv(&kv, KvWindow::new(64, 64), CodecPolicy::ZstdOnly);
        raw += blk.raw_bytes();
        comp += blk.compressed_bytes();
    }
    let measured_ratio = raw as f64 / comp as f64;
    println!("measured device KV ratio (Mechanism I + ZSTD): {measured_ratio:.2}x\n");

    // (a) feed it to the throughput model
    let mut shape = ModelShape::gpt_oss_120b_mxfp4();
    shape.kv_heads = 64;
    let mut cfg = SystemConfig::paper_default();
    // use the measured ratio for TRACE (static fn table approximated by
    // the nearest of the defaults; print both)
    println!(
        "model defaults use TRACE KV ratio 1.88 (paper Fig 15); measured here: {measured_ratio:.2}"
    );
    cfg = cfg.with_elastic_kv(2.0);
    let m = ThroughputModel::new(cfg, shape);

    println!("\n{:<10} {:>10} {:>10} {:>12} {:>14}", "ctx", "Plain", "GComp", "TRACE", "bottleneck");
    for ctx in [16384usize, 65536, 131072, 262144] {
        let p = m.eval(ctx, Design::Plain);
        let g = m.eval(ctx, Design::GComp);
        let t = m.eval(ctx, Design::Trace);
        println!(
            "{:<10} {:>10.2} {:>10.2} {:>12.2} {:>14}",
            ctx,
            p.tok_s,
            g.tok_s,
            t.tok_s,
            format!("{:?}", p.bottleneck)
        );
    }
    println!("\nOnce KV spills to CXL, the KV-aware representation keeps decode throughput near the");
    println!("pre-spill plateau while the word-major baselines fall off the bandwidth cliff.");
    Ok(())
}
