//! Long-context scenario: the paper's Fig. 12 story told through both the
//! analytic model and the functional device — now with device sharding.
//!
//! For a sweep of context lengths we (a) evaluate the trace-driven
//! throughput model (optionally with `--shards N` aggregating per-shard
//! DDR bandwidth) and (b) actually push the spilled KV volume through a
//! [`ShardedDevice`] via the transaction API, reporting the measured
//! compression ratio and the modeled aggregate read bandwidth — closing
//! the loop between §IV-B and §IV-C.
//!
//! Run: `cargo run --release --example longcontext_sweep -- --shards 4`

use trace_cxl::bitplane::KvWindow;
use trace_cxl::codec::CodecPolicy;
use trace_cxl::cxl::{
    Design, MemDevice, ShardedDevice, SubmissionQueue, Transaction, STRIPE_BYTES,
};
use trace_cxl::gen::KvGen;
use trace_cxl::sysmodel::{ModelShape, OverlapMode, SystemConfig, ThroughputModel};
use trace_cxl::util::cli::Args;
use trace_cxl::util::Rng;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let shards = args.get_usize("shards", 1).max(1);
    let overlap = args.flag("overlap");
    let mut rng = Rng::new(3);

    // (b) push calibrated KV windows through the (sharded) functional
    // device and measure ratio + modeled aggregate read bandwidth
    let mut dev = ShardedDevice::new(shards, Design::Trace, CodecPolicy::ZstdOnly);
    let mut sq = SubmissionQueue::new();
    let mut addr = 0u64;
    for layer in 0..8 {
        let g = KvGen::for_layer(64, layer * 4, 32);
        let kv = g.generate(&mut rng, 64);
        sq.submit(Transaction::WriteKv {
            block_addr: addr,
            words: kv,
            window: KvWindow::new(64, 64),
        });
        addr += STRIPE_BYTES;
    }
    for c in dev.drain(&mut sq) {
        c.result?;
    }
    let measured_ratio = dev.overall_ratio();
    println!("measured device KV ratio (Mechanism I + ZSTD): {measured_ratio:.2}x");

    dev.reset_time();
    let mut sq = SubmissionQueue::new();
    for i in 0..8u64 {
        sq.submit(Transaction::ReadFull { block_addr: i * STRIPE_BYTES });
    }
    let read_bytes: u64 = dev.drain(&mut sq).iter().map(|c| c.stats.dram_bytes_read).sum();
    println!(
        "aggregate read bandwidth over {} shard(s): {:.1} GB/s ({} read in {:.0} ns)\n",
        shards,
        read_bytes as f64 / dev.elapsed_ns(),
        read_bytes,
        dev.elapsed_ns()
    );

    // (a) feed it to the throughput model
    let mut shape = ModelShape::gpt_oss_120b_mxfp4();
    shape.kv_heads = 64;
    let mut cfg = SystemConfig::paper_default();
    println!(
        "model defaults use TRACE KV ratio 1.88 (paper Fig 15); measured here: {measured_ratio:.2}"
    );
    cfg = cfg.with_elastic_kv(2.0).with_shards(shards);
    // headline table stays on the paper's bandwidth-bottleneck closed
    // form (OverlapMode::Overlapped — the SystemConfig default)
    let m = ThroughputModel::new(cfg.clone(), shape.clone());

    println!("\n{:<10} {:>10} {:>10} {:>12} {:>14}", "ctx", "Plain", "GComp", "TRACE", "bottleneck");
    for ctx in [16384usize, 65536, 131072, 262144] {
        let p = m.eval(ctx, Design::Plain);
        let g = m.eval(ctx, Design::GComp);
        let t = m.eval(ctx, Design::Trace);
        println!(
            "{:<10} {:>10.2} {:>10.2} {:>12.2} {:>14}",
            ctx,
            p.tok_s,
            g.tok_s,
            t.tok_s,
            format!("{:?}", p.bottleneck)
        );
    }

    if overlap {
        // --overlap: what the pipelined engine buys over the serial one
        // at each context (identical pre-spill, by construction)
        let m_ser =
            ThroughputModel::new(cfg.clone().with_overlap(OverlapMode::Serial), shape.clone());
        let m_ovl = ThroughputModel::new(cfg.with_overlap(OverlapMode::Overlapped), shape);
        println!("\n{:<10} {:>18} {:>18}", "ctx", "TRACE serial", "TRACE overlapped");
        for ctx in [16384usize, 65536, 131072, 262144] {
            let s = m_ser.eval(ctx, Design::Trace);
            let o = m_ovl.eval(ctx, Design::Trace);
            println!("{:<10} {:>18.2} {:>18.2}", ctx, s.tok_s, o.tok_s);
        }
    }
    println!("\nOnce KV spills to CXL, the KV-aware representation keeps decode throughput near the");
    println!("pre-spill plateau while the word-major baselines fall off the bandwidth cliff;");
    println!("sharding multiplies the device-side ceiling until the shared link takes over, and");
    println!("(--overlap) overlapping fetch with compute hides whatever CXL time remains.");
    Ok(())
}
