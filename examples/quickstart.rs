//! Quickstart: the TRACE device through the transaction API.
//!
//! Queue a KV window and a weight block into each device design as
//! `WriteKv`/`WriteWeights` transactions, read them back bit-exactly with
//! `ReadFull`, and compare stored footprints and reduced-precision
//! (`ReadView`) fetch traffic.
//!
//! Run: `cargo run --release --example quickstart`

use trace_cxl::bitplane::{KvWindow, PrecisionView};
use trace_cxl::codec::CodecPolicy;
use trace_cxl::cxl::{CxlDevice, Design, MemDevice, SubmissionQueue, Transaction};
use trace_cxl::gen::{KvGen, WeightGen};
use trace_cxl::util::stats::human_bytes;
use trace_cxl::util::Rng;

fn main() -> anyhow::Result<()> {
    let mut rng = Rng::new(1);
    let kv = KvGen::default_for(64).generate(&mut rng, 64); // 64 tokens x 64 ch
    let weights = WeightGen::default_for(512).generate(&mut rng, 2048); // one 4 KB block

    println!("== TRACE quickstart: one KV window + one weight block ==\n");
    for design in [Design::Plain, Design::GComp, Design::Trace] {
        let mut dev = CxlDevice::new(design, CodecPolicy::AllBest);

        // writes go through the submission queue as typed transactions
        let mut sq = SubmissionQueue::new();
        sq.submit(Transaction::WriteKv {
            block_addr: 0x0000,
            words: kv.clone(),
            window: KvWindow::new(64, 64),
        });
        sq.submit(Transaction::WriteWeights {
            block_addr: 0x4000,
            words: weights.clone(),
            fmt: trace_cxl::formats::Fmt::Bf16,
        });
        for completion in dev.drain(&mut sq) {
            completion.result?;
        }

        // lossless read-back is bit-exact on every design
        let kv_back = dev.submit_one(Transaction::ReadFull { block_addr: 0x0000 })?.into_words()?;
        let w_back = dev.submit_one(Transaction::ReadFull { block_addr: 0x4000 })?.into_words()?;
        assert_eq!(kv_back, kv);
        assert_eq!(w_back, weights);

        // a reduced-precision alias read (sign+exp+3 mantissa planes)
        let before = dev.stats().dram_bytes_read;
        dev.submit_one(Transaction::ReadView {
            block_addr: 0x0000,
            view: PrecisionView::bf16_mantissa(3, 1),
        })?;
        let view_bytes = dev.stats().dram_bytes_read - before;

        println!(
            "{:<10}  stored {:>10}  (ratio {:>5.2}x)   FP12-alias fetch: {:>8}",
            design.name(),
            human_bytes(dev.footprint_bytes() as f64),
            dev.overall_ratio(),
            human_bytes(view_bytes as f64),
        );
    }
    println!("\nTRACE stores less and fetches fewer bytes for reduced-precision views,");
    println!("while every design returns identical host-visible values (paper §III-D).");
    Ok(())
}
