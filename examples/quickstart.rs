//! Quickstart: the TRACE device in ten lines.
//!
//! Write a KV window and a weight block into each device design, read them
//! back bit-exactly, and compare stored footprints and reduced-precision
//! fetch traffic.
//!
//! Run: `cargo run --release --example quickstart`

use trace_cxl::bitplane::{KvWindow, PrecisionView};
use trace_cxl::codec::CodecPolicy;
use trace_cxl::cxl::{CxlDevice, Design};
use trace_cxl::gen::{KvGen, WeightGen};
use trace_cxl::util::stats::human_bytes;
use trace_cxl::util::Rng;

fn main() -> anyhow::Result<()> {
    let mut rng = Rng::new(1);
    let kv = KvGen::default_for(64).generate(&mut rng, 64); // 64 tokens x 64 ch
    let weights = WeightGen::default_for(512).generate(&mut rng, 2048); // one 4 KB block

    println!("== TRACE quickstart: one KV window + one weight block ==\n");
    for design in [Design::Plain, Design::GComp, Design::Trace] {
        let mut dev = CxlDevice::new(design, CodecPolicy::AllBest);
        dev.write_kv(0x0000, &kv, KvWindow::new(64, 64));
        dev.write_weights(0x4000, &weights, trace_cxl::formats::Fmt::Bf16);

        // lossless read-back is bit-exact on every design
        assert_eq!(dev.read(0x0000)?, kv);
        assert_eq!(dev.read(0x4000)?, weights);

        // a reduced-precision alias read (sign+exp+3 mantissa planes)
        let before = dev.stats.dram_bytes_read;
        dev.read_view(0x0000, &PrecisionView::bf16_mantissa(3, 1))?;
        let view_bytes = dev.stats.dram_bytes_read - before;

        println!(
            "{:<10}  stored {:>10}  (ratio {:>5.2}x)   FP12-alias fetch: {:>8}",
            design.name(),
            human_bytes(dev.footprint_bytes() as f64),
            dev.overall_ratio(),
            human_bytes(view_bytes as f64),
        );
    }
    println!("\nTRACE stores less and fetches fewer bytes for reduced-precision views,");
    println!("while every design returns identical host-visible values (paper §III-D).");
    Ok(())
}
