"""AOT pipeline test: run aot.py with tiny dims into a temp dir and check
that the artifacts are complete and well-formed (HLO text parses as text,
manifest fields match the model, params.bin has the declared size)."""

import json
import os
import subprocess
import sys

import pytest

REPO_PY = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.fixture(scope="module")
def artifacts(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    env = dict(os.environ)
    env["TRACE_TRAIN_STEPS"] = "2"
    r = subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", str(out), "--test-dims"],
        cwd=REPO_PY,
        env=env,
        capture_output=True,
        text=True,
        timeout=600,
    )
    assert r.returncode == 0, r.stdout + r.stderr
    return out


def test_all_files_present(artifacts):
    for f in ["manifest.json", "decode_step.hlo.txt", "prefill.hlo.txt", "params.bin", "train_log.json"]:
        assert (artifacts / f).exists(), f


def _numel(shape):
    n = 1
    for s in shape:
        n *= s
    return n


def test_manifest_consistent(artifacts):
    m = json.loads((artifacts / "manifest.json").read_text())
    d = m["dims"]
    assert d["layers"] == 2 and d["vocab"] == 128  # TEST_DIMS
    total = sum(4 * _numel(p["shape"]) for p in m["params"])
    assert (artifacts / "params.bin").stat().st_size == total
    # offsets are sorted and contiguous
    offs = [p["offset"] for p in m["params"]]
    assert offs == sorted(offs)


def test_hlo_is_text(artifacts):
    head = (artifacts / "decode_step.hlo.txt").read_text()[:200]
    assert "HloModule" in head
    head2 = (artifacts / "prefill.hlo.txt").read_text()[:200]
    assert "HloModule" in head2


def test_train_log_has_losses(artifacts):
    log = json.loads((artifacts / "train_log.json").read_text())
    assert log["steps"] == 2
    assert all(l > 0 for l in log["loss"])
