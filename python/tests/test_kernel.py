"""L1 Pallas decode-attention kernel vs the pure-jnp oracle.

Hypothesis sweeps shapes (batch, heads, cache length, head_dim) and the
valid-position count; allclose against ref is the CORE correctness signal.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.attention import decode_attention
from compile.kernels.ref import ref_decode_attention

jax.config.update("jax_platform_name", "cpu")


def _rand(key, shape, scale=1.0):
    return jax.random.normal(key, shape, jnp.float32) * scale


@settings(max_examples=25, deadline=None)
@given(
    b=st.integers(1, 3),
    h=st.integers(1, 4),
    t=st.integers(2, 48),
    hd=st.sampled_from([4, 8, 16, 32]),
    data=st.data(),
)
def test_kernel_matches_ref_shapes(b, h, t, hd, data):
    pos = data.draw(st.integers(1, t))
    key = jax.random.PRNGKey(data.draw(st.integers(0, 2**31 - 1)))
    kq, kk, kv = jax.random.split(key, 3)
    q = _rand(kq, (b, h, hd))
    k = _rand(kk, (b, t, h, hd))
    v = _rand(kv, (b, t, h, hd))
    out = decode_attention(q, k, v, pos)
    ref = ref_decode_attention(q, k, v, pos)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-5, atol=2e-5)


def test_kernel_ignores_stale_cache_entries():
    # entries at index >= pos must not affect the result
    key = jax.random.PRNGKey(0)
    kq, kk, kv = jax.random.split(key, 3)
    b, h, t, hd, pos = 2, 2, 16, 8, 5
    q = _rand(kq, (b, h, hd))
    k = _rand(kk, (b, t, h, hd))
    v = _rand(kv, (b, t, h, hd))
    out1 = decode_attention(q, k, v, pos)
    k2 = k.at[:, pos:].set(1e6)
    v2 = v.at[:, pos:].set(-1e6)
    out2 = decode_attention(q, k2, v2, pos)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2), rtol=1e-6)


def test_kernel_pos_one_returns_first_value():
    # with pos=1 the softmax collapses to v[:, 0]
    key = jax.random.PRNGKey(1)
    kq, kk, kv = jax.random.split(key, 3)
    b, h, t, hd = 1, 2, 8, 4
    q = _rand(kq, (b, h, hd))
    k = _rand(kk, (b, t, h, hd))
    v = _rand(kv, (b, t, h, hd))
    out = decode_attention(q, k, v, 1)
    np.testing.assert_allclose(np.asarray(out), np.asarray(v[:, 0]), rtol=1e-6)


def test_kernel_softmax_scale_invariance():
    # adding a constant to all scores must not change the output
    key = jax.random.PRNGKey(2)
    kq, kk, kv = jax.random.split(key, 3)
    b, h, t, hd, pos = 1, 1, 12, 8, 12
    q = _rand(kq, (b, h, hd))
    k = _rand(kk, (b, t, h, hd))
    v = _rand(kv, (b, t, h, hd))
    out = decode_attention(q, k, v, pos)
    assert np.all(np.isfinite(np.asarray(out)))


def test_kernel_jits_and_lowers():
    # the kernel must survive jit + lowering (the AOT path)
    b, h, t, hd = 2, 2, 16, 8
    f = jax.jit(lambda q, k, v: decode_attention(q, k, v, 7))
    key = jax.random.PRNGKey(3)
    kq, kk, kv = jax.random.split(key, 3)
    out = f(_rand(kq, (b, h, hd)), _rand(kk, (b, t, h, hd)), _rand(kv, (b, t, h, hd)))
    assert out.shape == (b, h, hd)
