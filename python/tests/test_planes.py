"""L1 bit-plane reconstruction kernel vs the numpy oracle.

Property: for BF16-representable values, to_planes -> reconstruct (full
mask) is the identity; partial masks zero exactly the unselected planes —
mirroring the Rust `bitplane` tests so both implementations agree on the
format (paper Eq. 2 / Eq. 6 semantics).
"""

import jax
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels.planes import reconstruct_bf16
from compile.kernels.ref import bf16_round, ref_reconstruct_bf16, to_planes

jax.config.update("jax_platform_name", "cpu")


def _bf16_values(rng, m):
    return bf16_round(rng.standard_normal(m).astype(np.float32) * 4.0)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), m=st.sampled_from([512, 1024]))
def test_full_mask_roundtrip(seed, m):
    rng = np.random.default_rng(seed)
    vals = _bf16_values(rng, m)
    planes = to_planes(vals)
    mask = np.ones(16, np.int32)
    out = np.asarray(reconstruct_bf16(planes, mask))
    np.testing.assert_array_equal(out.view(np.uint32), vals.view(np.uint32))


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), mask_bits=st.integers(0, 2**16 - 1))
def test_partial_mask_matches_ref(seed, mask_bits):
    rng = np.random.default_rng(seed)
    vals = _bf16_values(rng, 512)
    planes = to_planes(vals)
    mask = np.array([(mask_bits >> i) & 1 for i in range(16)], np.int32)
    out = np.asarray(reconstruct_bf16(planes, mask))
    ref = ref_reconstruct_bf16(planes, mask)
    np.testing.assert_array_equal(out.view(np.uint32), ref.view(np.uint32))


def test_exponent_only_view_keeps_magnitude_class():
    # the S_req of a sign+exponent view: mantissa planes dropped
    rng = np.random.default_rng(7)
    vals = _bf16_values(rng, 512)
    planes = to_planes(vals)
    mask = np.zeros(16, np.int32)
    mask[15] = 1  # sign
    mask[7:15] = 1  # exponent
    out = np.asarray(reconstruct_bf16(planes, mask))
    nz = vals != 0
    # truncation towards zero: |out| <= |vals| < 2|out| for normal values
    assert np.all(np.abs(out[nz]) <= np.abs(vals[nz]))
    assert np.all(np.sign(out[nz]) == np.sign(vals[nz]))


def test_zero_mask_gives_zero():
    rng = np.random.default_rng(9)
    vals = _bf16_values(rng, 512)
    out = np.asarray(reconstruct_bf16(to_planes(vals), np.zeros(16, np.int32)))
    assert np.all(out == 0.0)
