"""L2 model tests (tiny dims): shapes, KV-cache consistency between
prefill and decode, and determinism."""

import jax
import jax.numpy as jnp
import numpy as np

from compile.model import (
    TEST_DIMS,
    decode_step,
    init_params,
    loss_fn,
    prefill,
    train_forward,
)

jax.config.update("jax_platform_name", "cpu")

DIMS = TEST_DIMS


def _params():
    return init_params(DIMS, jax.random.PRNGKey(0))


def _prompt(seed=1):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.integers(0, DIMS.vocab, (DIMS.batch, DIMS.t_prompt)), jnp.int32)


def test_prefill_shapes():
    p = _params()
    logits, k, v = prefill(p, _prompt(), DIMS)
    assert logits.shape == (DIMS.batch, DIMS.vocab)
    assert k.shape == (DIMS.layers, DIMS.batch, DIMS.t_prompt, DIMS.heads, DIMS.head_dim)
    assert v.shape == k.shape
    assert np.all(np.isfinite(np.asarray(logits)))


def test_decode_shapes():
    p = _params()
    kshape = (DIMS.layers, DIMS.batch, DIMS.t_max, DIMS.heads, DIMS.head_dim)
    k = jnp.zeros(kshape)
    v = jnp.zeros(kshape)
    toks = jnp.asarray([1, 2], jnp.int32)
    logits, k_new, v_new = decode_step(p, k, v, toks, jnp.asarray([0], jnp.int32), DIMS)
    assert logits.shape == (DIMS.batch, DIMS.vocab)
    assert k_new.shape == (DIMS.layers, DIMS.batch, DIMS.heads, DIMS.head_dim)
    assert v_new.shape == k_new.shape


def test_prefill_then_decode_matches_full_forward():
    """The AR consistency check: prefill a prompt, decode the next token
    with the cached KV, and compare against the all-position forward over
    the extended sequence."""
    p = _params()
    prompt = _prompt(3)
    logits_pre, k_pre, v_pre = prefill(p, prompt, DIMS)
    next_tok = jnp.argmax(logits_pre, axis=-1).astype(jnp.int32)  # [B]

    # pad prefill KV into the decode cache layout
    kshape = (DIMS.layers, DIMS.batch, DIMS.t_max, DIMS.heads, DIMS.head_dim)
    k = jnp.zeros(kshape).at[:, :, : DIMS.t_prompt].set(k_pre)
    v = jnp.zeros(kshape).at[:, :, : DIMS.t_prompt].set(v_pre)
    logits_dec, _, _ = decode_step(p, k, v, next_tok, jnp.asarray([DIMS.t_prompt], jnp.int32), DIMS)

    # ground truth: all-position logits over prompt + next token
    ext = jnp.concatenate([prompt, next_tok[:, None]], axis=1)
    logits_all = train_forward(p, ext, DIMS)[:, -1]
    np.testing.assert_allclose(
        np.asarray(logits_dec), np.asarray(logits_all), rtol=2e-4, atol=2e-4
    )


def test_decode_deterministic():
    p = _params()
    kshape = (DIMS.layers, DIMS.batch, DIMS.t_max, DIMS.heads, DIMS.head_dim)
    k = jax.random.normal(jax.random.PRNGKey(5), kshape)
    v = jax.random.normal(jax.random.PRNGKey(6), kshape)
    toks = jnp.asarray([3, 4], jnp.int32)
    pos = jnp.asarray([7], jnp.int32)
    a = decode_step(p, k, v, toks, pos, DIMS)[0]
    b = decode_step(p, k, v, toks, pos, DIMS)[0]
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_loss_decreases_direction():
    # a single SGD step in the gradient direction must reduce the loss
    p = _params()
    rng = np.random.default_rng(11)
    toks = jnp.asarray(rng.integers(0, DIMS.vocab, (2, DIMS.t_prompt)), jnp.int32)
    l0, g = jax.value_and_grad(lambda q: loss_fn(q, toks, DIMS))(p)
    p2 = jax.tree.map(lambda a, b: a - 0.05 * b, p, g)
    l1 = loss_fn(p2, toks, DIMS)
    assert float(l1) < float(l0)
