"""L1 Pallas kernel: bit-plane reconstruction (the paper's R operator).

Reassembles BF16 values from disaggregated bit-planes under a plane mask —
the arithmetic-reconstruction stage of TRACE's read path (Eq. 7, step 2),
expressed as a TPU-style kernel: each grid program reconstructs one tile
of M elements from its 16 plane rows held in VMEM, then bit-casts the
assembled word to f32 (BF16 occupies the high half of an f32 word).

This is where the paper's controller logic meets the accelerator: a
software fallback for hosts whose CXL device is a plain (non-TRACE)
expander — fetch raw planes, reconstruct on-chip. Validated against the
pure-jnp oracle in ref.py and, transitively, against the Rust
`bitplane::transpose_from_planes` via the shared test vectors.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BITS = 16


def _recon_kernel(planes_ref, mask_ref, o_ref):
    """planes_ref: [BITS, M] int32 (0/1); mask_ref: [BITS] int32 (0/1);
    o_ref: [M] f32 — bf16 value assembled from masked planes."""
    m = o_ref.shape[0]
    word = jnp.zeros((m,), jnp.int32)
    for i in range(BITS):  # bit position i contributes plane row BITS-1-i
        plane = planes_ref[BITS - 1 - i, :]
        word = word | ((plane & mask_ref[i]) << i)
    # BF16 word -> f32 bits (<< 16), then bitcast
    o_ref[:] = jax.lax.bitcast_convert_type(word << 16, jnp.float32)


def reconstruct_bf16(planes, mask):
    """Reconstruct BF16 values (as f32) from bit-planes.

    Args:
      planes: [16, M] int32 of 0/1 — row 0 is the MSB plane (paper Eq. 2
        ordering), row 15 the LSB plane.
      mask: [16] int32 of 0/1 — mask[i] selects the plane for *bit
        position* i (the S_req row filter of Eq. 6).

    Returns: [M] f32 — the BF16 values with unselected planes zeroed.
    """
    _, m = planes.shape
    tile = min(m, 512)
    assert m % tile == 0, "M must divide into tiles"
    return pl.pallas_call(
        _recon_kernel,
        grid=(m // tile,),
        in_specs=[
            pl.BlockSpec((BITS, tile), lambda i: (0, i)),
            pl.BlockSpec((BITS,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((tile,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((m,), jnp.float32),
        interpret=True,
    )(planes.astype(jnp.int32), mask.astype(jnp.int32))
