"""L1 Pallas kernel: single-token decode attention.

The serving hot path: one query token attends over the KV cache. The
kernel is written TPU-style — the grid tiles (batch, head), each program
instance holds one head's (T, head_dim) K/V tile in VMEM, computes masked
softmax scores, and writes one (head_dim,) output row.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper's
contribution is a memory controller, not a GPU kernel, so L1's job here is
the *consumer* of TRACE-served KV (decode attention) plus the
reconstruction math (see planes.py). BlockSpec expresses the HBM->VMEM
schedule: K/V stream in per (b, h) tile; validity is bounded by ``pos``
masking.

Lowered with ``interpret=True`` so the CPU PJRT client can execute the
resulting HLO (real-TPU lowering emits a Mosaic custom call).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _decode_attn_kernel(pos_ref, q_ref, k_ref, v_ref, o_ref):
    """One (batch, head) tile: out = softmax(mask(K q / sqrt(d))) @ V.

    Block shapes (grid dims collapsed to 1):
      pos_ref: [1]  q_ref: [1, 1, hd]  k_ref/v_ref: [1, T, 1, hd]
      o_ref: [1, 1, hd]
    """
    q = q_ref[0, 0, :]  # [hd]
    k = k_ref[0, :, 0, :]  # [T, hd]
    v = v_ref[0, :, 0, :]  # [T, hd]
    t = k.shape[0]
    hd = q.shape[0]

    scale = 1.0 / jnp.sqrt(jnp.float32(hd))
    scores = jnp.sum(k * q[None, :], axis=-1) * scale  # [T]

    pos = pos_ref[0]
    idx = jax.lax.broadcasted_iota(jnp.int32, (t,), 0)
    valid = idx < pos
    scores = jnp.where(valid, scores, jnp.float32(-1e30))

    m = jnp.max(scores)
    p = jnp.where(valid, jnp.exp(scores - m), 0.0)
    denom = jnp.sum(p)
    o_ref[0, 0, :] = jnp.sum(p[:, None] * v, axis=0) / denom


def decode_attention(q, k, v, pos):
    """Masked decode attention via the Pallas kernel.

    Args:
      q: [B, H, hd] current-token queries (f32).
      k, v: [B, T, H, hd] KV cache (entries at index >= pos are ignored).
      pos: scalar int32 — attend over cache positions [0, pos).

    Returns: [B, H, hd] attention outputs (f32).
    """
    b, h, hd = q.shape
    t = k.shape[1]
    pos_arr = jnp.asarray(pos, jnp.int32).reshape((1,))
    return pl.pallas_call(
        _decode_attn_kernel,
        grid=(b, h),
        in_specs=[
            pl.BlockSpec((1,), lambda i, j: (0,)),
            pl.BlockSpec((1, 1, hd), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, t, 1, hd), lambda i, j: (i, 0, j, 0)),
            pl.BlockSpec((1, t, 1, hd), lambda i, j: (i, 0, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, hd), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, hd), jnp.float32),
        interpret=True,
    )(pos_arr, q, k, v)
