"""Pure-jnp / numpy oracles for the L1 kernels — the CORE correctness
signal: every Pallas kernel must match its reference bit-for-bit (planes)
or to float tolerance (attention)."""

import jax
import jax.numpy as jnp
import numpy as np


def ref_decode_attention(q, k, v, pos):
    """Reference masked decode attention.

    q: [B, H, hd]; k, v: [B, T, H, hd]; pos: int — attend over [0, pos).
    Returns [B, H, hd].
    """
    b, h, hd = q.shape
    t = k.shape[1]
    scale = 1.0 / jnp.sqrt(jnp.float32(hd))
    # scores[b, h, t]
    scores = jnp.einsum("bhd,bthd->bht", q, k) * scale
    idx = jnp.arange(t)[None, None, :]
    valid = idx < pos
    scores = jnp.where(valid, scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    p = jnp.where(valid, p, 0.0)
    return jnp.einsum("bht,bthd->bhd", p, v)


def ref_reconstruct_bf16(planes, mask):
    """Reference bit-plane reconstruction (numpy).

    planes: [16, M] 0/1, row 0 = MSB plane; mask: [16] 0/1 over bit
    positions. Returns [M] f32.
    """
    planes = np.asarray(planes, np.uint32)
    mask = np.asarray(mask, np.uint32)
    m = planes.shape[1]
    word = np.zeros(m, np.uint32)
    for i in range(16):
        word |= (planes[15 - i, :] & mask[i]) << i
    return (word.astype(np.uint32) << 16).view(np.float32)


def bf16_round(x):
    """Round f32 to bf16 and back (RTNE), numpy."""
    bits = np.asarray(x, np.float32).view(np.uint32)
    lsb = (bits >> 16) & 1
    rounded = bits + 0x7FFF + lsb
    out = (rounded & 0xFFFF0000).astype(np.uint32)
    return out.view(np.float32)


def to_planes(values_f32):
    """Disaggregate f32-held BF16 values into [16, M] 0/1 planes
    (row 0 = MSB), numpy — mirrors rust `transpose_to_planes`."""
    words = (np.asarray(values_f32, np.float32).view(np.uint32) >> 16).astype(np.uint32)
    m = words.shape[0]
    planes = np.zeros((16, m), np.int32)
    for i in range(16):
        planes[15 - i, :] = (words >> i) & 1
    return planes
