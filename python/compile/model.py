"""L2: the ~100M-parameter GPT-style transformer (JAX), calling the L1
Pallas decode-attention kernel on the serving hot path.

Architecture: pre-RMSNorm decoder blocks, learned positional embeddings,
tied input/output embedding. Parameters are stacked per layer so both
executables take a flat 9-tensor parameter list (see PARAM_ORDER), which
is also the order `rust/src/runtime/pjrt.rs` feeds them in.

Exported entry points (AOT-lowered by aot.py):
  * prefill(params, tokens[B,Tp]) -> (logits[B,V], k[L,B,Tp,H,hd], v[...])
  * decode_step(params, k[L,B,T,H,hd], v[...], tokens[B], pos[1])
      -> (logits[B,V], k_new[L,B,H,hd], v_new[L,B,H,hd])
  * train_forward — all-position logits, used by the optional calibration
    training in aot.py (build-time only).
"""

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from .kernels.attention import decode_attention


@dataclass(frozen=True)
class Dims:
    layers: int = 12
    batch: int = 2
    t_max: int = 256
    t_prompt: int = 32
    d_model: int = 768
    heads: int = 12
    head_dim: int = 64
    ffn: int = 3072
    vocab: int = 16384

    @property
    def kv_channels(self):
        return 2 * self.heads * self.head_dim


# Tiny dims for fast tests.
TEST_DIMS = Dims(layers=2, batch=2, t_max=32, t_prompt=8, d_model=32,
                 heads=2, head_dim=16, ffn=64, vocab=128)

PARAM_ORDER = [
    "emb", "pos_emb", "ln1", "wqkv", "wo", "ln2", "win", "wout", "lnf",
]


def init_params(dims: Dims, key):
    """Seeded initialization (scaled-normal, GPT-2-style)."""
    d, f, v = dims.d_model, dims.ffn, dims.vocab
    L = dims.layers
    ks = jax.random.split(key, 8)
    s = 0.02
    return {
        "emb": jax.random.normal(ks[0], (v, d), jnp.float32) * s,
        "pos_emb": jax.random.normal(ks[1], (dims.t_max, d), jnp.float32) * s,
        "ln1": jnp.ones((L, d), jnp.float32),
        "wqkv": jax.random.normal(ks[2], (L, d, 3 * d), jnp.float32) * s,
        "wo": jax.random.normal(ks[3], (L, d, d), jnp.float32) * (s / jnp.sqrt(2.0 * L)),
        "ln2": jnp.ones((L, d), jnp.float32),
        "win": jax.random.normal(ks[4], (L, d, f), jnp.float32) * s,
        "wout": jax.random.normal(ks[5], (L, f, d), jnp.float32) * (s / jnp.sqrt(2.0 * L)),
        "lnf": jnp.ones((d,), jnp.float32),
    }


def _rms(x, g):
    return x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + 1e-6) * g


def _split_heads(x, dims: Dims):
    # [..., D] -> [..., H, hd]
    return x.reshape(x.shape[:-1] + (dims.heads, dims.head_dim))


def decode_step(params, k_cache, v_cache, tokens, pos, dims: Dims):
    """One decode step for the whole batch.

    k_cache/v_cache: [L, B, T, H, hd] with valid entries in [0, pos).
    tokens: [B] int32 current tokens. pos: [1] int32.
    Returns (logits [B, V], k_new [L, B, H, hd], v_new [L, B, H, hd]).
    """
    p = pos[0]
    x = params["emb"][tokens] + jax.lax.dynamic_index_in_dim(
        params["pos_emb"], p, axis=0, keepdims=False)
    k_news, v_news = [], []
    for l in range(dims.layers):
        h = _rms(x, params["ln1"][l])
        qkv = h @ params["wqkv"][l]
        q, k, v = jnp.split(qkv, 3, axis=-1)
        q = _split_heads(q, dims)  # [B, H, hd]
        k = _split_heads(k, dims)
        v = _split_heads(v, dims)
        k_news.append(k)
        v_news.append(v)
        # place the current entry at index p so attention covers [0, p]
        k_full = jax.lax.dynamic_update_slice_in_dim(
            k_cache[l], k[:, None], p, axis=1)  # [B, T, H, hd]
        v_full = jax.lax.dynamic_update_slice_in_dim(
            v_cache[l], v[:, None], p, axis=1)
        attn = decode_attention(q, k_full, v_full, p + 1)  # [B, H, hd]
        x = x + attn.reshape(attn.shape[0], -1) @ params["wo"][l]
        h2 = _rms(x, params["ln2"][l])
        x = x + jax.nn.gelu(h2 @ params["win"][l]) @ params["wout"][l]
    logits = _rms(x, params["lnf"]) @ params["emb"].T
    return logits, jnp.stack(k_news), jnp.stack(v_news)


def _causal_forward(params, tokens, dims: Dims):
    """Full-sequence forward (jnp attention): returns (x_all, k_all, v_all).

    tokens: [B, T]. x_all: [B, T, D]; k_all/v_all: [L, B, T, H, hd].
    """
    b, t = tokens.shape
    x = params["emb"][tokens] + params["pos_emb"][:t][None]
    idx = jnp.arange(t)
    causal = idx[None, :] <= idx[:, None]  # [Tq, Tk]
    ks, vs = [], []
    scale = 1.0 / jnp.sqrt(jnp.float32(dims.head_dim))
    for l in range(dims.layers):
        h = _rms(x, params["ln1"][l])
        qkv = h @ params["wqkv"][l]
        q, k, v = jnp.split(qkv, 3, axis=-1)
        q = _split_heads(q, dims)  # [B, T, H, hd]
        k = _split_heads(k, dims)
        v = _split_heads(v, dims)
        ks.append(k)
        vs.append(v)
        scores = jnp.einsum("bqhd,bkhd->bhqk", q, k) * scale
        scores = jnp.where(causal[None, None], scores, -1e30)
        pr = jax.nn.softmax(scores, axis=-1)
        attn = jnp.einsum("bhqk,bkhd->bqhd", pr, v)
        x = x + attn.reshape(b, t, -1) @ params["wo"][l]
        h2 = _rms(x, params["ln2"][l])
        x = x + jax.nn.gelu(h2 @ params["win"][l]) @ params["wout"][l]
    return _rms(x, params["lnf"]), jnp.stack(ks), jnp.stack(vs)


def prefill(params, tokens, dims: Dims):
    """Prefill over fixed-length prompts.

    tokens: [B, Tp] int32 (0-padded). Returns (last-position logits
    [B, V], k [L, B, Tp, H, hd], v [L, B, Tp, H, hd]).
    """
    x, k, v = _causal_forward(params, tokens, dims)
    logits = x[:, -1] @ params["emb"].T
    return logits, k, v


def train_forward(params, tokens, dims: Dims):
    """All-position logits [B, T, V] (build-time calibration training)."""
    x, _, _ = _causal_forward(params, tokens, dims)
    return x @ params["emb"].T


def loss_fn(params, tokens, dims: Dims):
    """Next-token cross entropy over a [B, T] batch."""
    logits = train_forward(params, tokens[:, :-1], dims)
    targets = tokens[:, 1:]
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(nll)
