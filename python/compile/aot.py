"""AOT driver: lower the L2 model to HLO *text* + emit params.bin and
manifest.json for the Rust runtime.

HLO text (NOT serialized HloModuleProto) is the interchange format: jax
>= 0.5 emits protos with 64-bit instruction ids which the image's
xla_extension 0.5.1 rejects; the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Optionally runs a short calibration training loop on the synthetic corpus
(Zipf + Markov, mirroring rust `gen::workload::SynthCorpus`) so the
exported weights and the KV they produce have non-degenerate statistics;
the loss curve is logged to artifacts/train_log.json and EXPERIMENTS.md.

Usage:  python -m compile.aot --out-dir ../artifacts [--train-steps N]
        [--test-dims]  (tiny shapes, used by pytest)
"""

import argparse
import json
import os
import struct
import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from .model import Dims, TEST_DIMS, PARAM_ORDER, decode_step, init_params, loss_fn, prefill


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def synth_corpus(vocab: int, n: int, seed: int) -> np.ndarray:
    """Zipf + Markov synthetic token stream (mirrors the Rust generator)."""
    rng = np.random.default_rng(seed)
    toks = np.zeros(n, np.int32)
    prev = 0
    for i in range(n):
        if rng.random() < 0.45:
            tok = (prev + 1 + rng.integers(0, 7)) % vocab
        else:
            u = max(rng.random(), 1e-9)
            tok = int(u ** -0.8 - 1.0) % vocab
        toks[i] = tok
        prev = tok
    return toks


def train(params, dims: Dims, steps: int, seed: int):
    """Brief Adam calibration training; returns (params, loss_log)."""
    if steps <= 0:
        return params, []
    lr = 3e-4
    b, t = 4, min(dims.t_prompt * 2, dims.t_max)
    corpus = synth_corpus(dims.vocab, b * t * (steps + 1) + 1, seed)

    grad_fn = jax.jit(jax.value_and_grad(lambda p, x: loss_fn(p, x, dims)))
    m = jax.tree.map(jnp.zeros_like, params)
    v = jax.tree.map(jnp.zeros_like, params)
    log = []
    for step in range(steps):
        off = step * b * t
        batch = corpus[off:off + b * t].reshape(b, t)
        loss, g = grad_fn(params, jnp.asarray(batch))
        b1, b2, eps = 0.9, 0.999, 1e-8
        m = jax.tree.map(lambda a, gg: b1 * a + (1 - b1) * gg, m, g)
        v = jax.tree.map(lambda a, gg: b2 * a + (1 - b2) * gg * gg, v, g)
        tcorr = step + 1
        params = jax.tree.map(
            lambda p, mm, vv: p - lr * (mm / (1 - b1 ** tcorr))
            / (jnp.sqrt(vv / (1 - b2 ** tcorr)) + eps),
            params, m, v,
        )
        log.append(float(loss))
        if step % 5 == 0 or step == steps - 1:
            print(f"  train step {step:4d} loss {float(loss):.4f}", flush=True)
    return params, log


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--train-steps", type=int, default=int(os.environ.get("TRACE_TRAIN_STEPS", "30")))
    ap.add_argument("--test-dims", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    dims = TEST_DIMS if args.test_dims else Dims()
    os.makedirs(args.out_dir, exist_ok=True)

    print(f"init params ({dims})", flush=True)
    params = init_params(dims, jax.random.PRNGKey(args.seed))
    params, loss_log = train(params, dims, args.train_steps, args.seed + 1)

    # ---- params.bin (f32 LE, PARAM_ORDER) + manifest entries
    specs = []
    offset = 0
    with open(os.path.join(args.out_dir, "params.bin"), "wb") as f:
        for name in PARAM_ORDER:
            arr = np.asarray(params[name], np.float32)
            f.write(arr.tobytes())
            specs.append({"name": name, "shape": list(arr.shape), "offset": offset})
            offset += arr.nbytes
    print(f"params.bin: {offset / 1e6:.1f} MB", flush=True)

    # ---- lower both entry points
    def decode_fn(*flat):
        p = dict(zip(PARAM_ORDER, flat[: len(PARAM_ORDER)]))
        k, v, toks, pos = flat[len(PARAM_ORDER):]
        return decode_step(p, k, v, toks, pos, dims)

    def prefill_fn(*flat):
        p = dict(zip(PARAM_ORDER, flat[: len(PARAM_ORDER)]))
        (toks,) = flat[len(PARAM_ORDER):]
        return prefill(p, toks, dims)

    param_specs = [
        jax.ShapeDtypeStruct(np.asarray(params[n]).shape, jnp.float32) for n in PARAM_ORDER
    ]
    kv_spec = jax.ShapeDtypeStruct(
        (dims.layers, dims.batch, dims.t_max, dims.heads, dims.head_dim), jnp.float32
    )
    tok_spec = jax.ShapeDtypeStruct((dims.batch,), jnp.int32)
    pos_spec = jax.ShapeDtypeStruct((1,), jnp.int32)
    prompt_spec = jax.ShapeDtypeStruct((dims.batch, dims.t_prompt), jnp.int32)

    print("lowering decode_step ...", flush=True)
    dec = jax.jit(decode_fn).lower(*param_specs, kv_spec, kv_spec, tok_spec, pos_spec)
    dec_text = to_hlo_text(dec)
    with open(os.path.join(args.out_dir, "decode_step.hlo.txt"), "w") as f:
        f.write(dec_text)
    print(f"decode_step.hlo.txt: {len(dec_text) / 1e6:.2f} MB", flush=True)

    print("lowering prefill ...", flush=True)
    pre = jax.jit(prefill_fn).lower(*param_specs, prompt_spec)
    pre_text = to_hlo_text(pre)
    with open(os.path.join(args.out_dir, "prefill.hlo.txt"), "w") as f:
        f.write(pre_text)
    print(f"prefill.hlo.txt: {len(pre_text) / 1e6:.2f} MB", flush=True)

    manifest = {
        "dims": {
            "layers": dims.layers,
            "batch": dims.batch,
            "t_max": dims.t_max,
            "t_prompt": dims.t_prompt,
            "d_model": dims.d_model,
            "heads": dims.heads,
            "head_dim": dims.head_dim,
            "ffn": dims.ffn,
            "vocab": dims.vocab,
        },
        "decode_hlo": "decode_step.hlo.txt",
        "prefill_hlo": "prefill.hlo.txt",
        "params_bin": "params.bin",
        "params": specs,
    }
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    with open(os.path.join(args.out_dir, "train_log.json"), "w") as f:
        json.dump({"steps": len(loss_log), "loss": loss_log}, f)
    print("manifest.json written; artifacts complete.", flush=True)


if __name__ == "__main__":
    sys.exit(main())
